//! Video sandbox: the EgoSchema / VideoAgent tool suite over a simulated
//! media store.
//!
//! Substitution for the paper's L40S tool server + OpenAI API (DESIGN.md
//! §3). Each task is a 3-minute video sliced into 2-second segments; the
//! sandbox is "a folder on the server" (§4.3): `load_video` and
//! `preprocess` mutate it (copy video + build memories), everything else is
//! read-only and annotated stateless — exactly the Appendix B/D setup.
//! Captions, localizations, and QA answers are generated deterministically
//! from (task seed, arguments); the caption tool charges simulated OpenAI
//! tokens, backing the §4.3 "3× token saving" accounting.

use super::env::{SandboxFactory, SandboxSnapshot, ToolExecutionEnvironment};
use super::latency::ego_tool_latency;
use crate::cache::{ToolCall, ToolResult};
use crate::util::rng::{fnv1a, Rng};

/// Number of 2-second segments in a 3-minute video.
pub const SEGMENTS: usize = 90;

/// The EgoSchema tool names.
pub const TOOLS: [&str; 6] = [
    "load_video",
    "preprocess",
    "object_memory_querying",
    "segment_localization",
    "caption_retrieval",
    "visual_question_answering",
];

/// Which tools mutate sandbox state (Appendix D).
pub fn tool_mutates(tool: &str) -> bool {
    matches!(tool, "load_video" | "preprocess")
}

/// The sandbox: per-task folder state.
pub struct VideoSandbox {
    seed: u64,
    video_loaded: bool,
    preprocessed: bool,
    running: bool,
}

impl VideoSandbox {
    pub fn new(seed: u64) -> VideoSandbox {
        VideoSandbox { seed, video_loaded: false, preprocessed: false, running: false }
    }

    fn caption(&self, segment: usize) -> String {
        let mut rng = Rng::new(self.seed ^ (segment as u64).wrapping_mul(0x517c_c1b7));
        let actors = ["#C camera wearer", "#O person in red", "#O person at table"];
        let verbs = ["picks up", "examines", "places", "cuts", "stirs", "washes"];
        let objects = ["a knife", "a bowl", "vegetables", "a phone", "a cloth", "a pan"];
        format!(
            "seg{segment}: {} {} {}",
            actors[rng.below(3) as usize],
            verbs[rng.below(6) as usize],
            objects[rng.below(6) as usize]
        )
    }

    fn require_ready(&self) -> Option<String> {
        if !self.video_loaded {
            return Some("error: no video loaded — call load_video first".into());
        }
        if !self.preprocessed {
            return Some("error: video not preprocessed — call preprocess first".into());
        }
        None
    }

    fn run_tool(&mut self, tool: &str, args: &str) -> (String, u64) {
        match tool {
            "load_video" => {
                self.video_loaded = true;
                (format!("loaded video '{args}' into sandbox"), 0)
            }
            "preprocess" => {
                if !self.video_loaded {
                    return ("error: no video loaded — call load_video first".into(), 0);
                }
                self.preprocessed = true;
                (
                    format!(
                        "preprocessed: {SEGMENTS} segments captioned, object memory built"
                    ),
                    0,
                )
            }
            "caption_retrieval" => {
                if let Some(e) = self.require_ready() {
                    return (e, 0);
                }
                // args: "(start, end)"
                let nums: Vec<usize> = args
                    .trim_matches(|c| c == '(' || c == ')')
                    .split(',')
                    .filter_map(|s| s.trim().parse().ok())
                    .collect();
                let (a, b) = match nums.as_slice() {
                    [a, b] => (*a, (*b).min(a + 15).min(SEGMENTS)),
                    _ => return ("error: caption_retrieval expects (start, end)".into(), 0),
                };
                let caps: Vec<String> = (a..b).map(|s| self.caption(s)).collect();
                // OpenAI-generated captions: tokens ∝ caption count.
                let tokens = 40 * caps.len() as u64 + 120;
                (caps.join("\n"), tokens)
            }
            "segment_localization" => {
                if let Some(e) = self.require_ready() {
                    return (e, 0);
                }
                let mut rng = Rng::new(self.seed ^ fnv1a(args.as_bytes()));
                let mut segs: Vec<usize> =
                    (0..5).map(|_| rng.below(SEGMENTS as u64) as usize).collect();
                segs.sort();
                (format!("top-5 segments for '{args}': {segs:?}"), 0)
            }
            "visual_question_answering" => {
                if let Some(e) = self.require_ready() {
                    return (e, 0);
                }
                let mut rng = Rng::new(self.seed ^ fnv1a(args.as_bytes()).rotate_left(9));
                let answers = ["yes", "no", "unclear", "partially"];
                let seg: usize = args
                    .rsplit(',')
                    .next()
                    .and_then(|s| s.trim().trim_end_matches(')').parse().ok())
                    .unwrap_or(0);
                (
                    format!(
                        "segment {seg}: {} | answer: {}",
                        self.caption(seg.min(SEGMENTS - 1)),
                        answers[rng.below(4) as usize]
                    ),
                    90,
                )
            }
            "object_memory_querying" => {
                if let Some(e) = self.require_ready() {
                    return (e, 0);
                }
                let mut rng = Rng::new(self.seed ^ fnv1a(args.as_bytes()).rotate_left(21));
                let n = 1 + rng.below(4);
                let segs: Vec<usize> =
                    (0..n).map(|_| rng.below(SEGMENTS as u64) as usize).collect();
                // Internal agent loop with an OpenAI model: expensive.
                (format!("object memory: '{args}' → appears in segments {segs:?}"), 600)
            }
            other => (format!("error: unknown tool {other}"), 0),
        }
    }
}

impl ToolExecutionEnvironment for VideoSandbox {
    fn start(&mut self) -> f64 {
        self.running = true;
        0.02 // folder creation
    }

    fn stop(&mut self) -> f64 {
        self.running = false;
        0.01
    }

    fn execute(&mut self, call: &ToolCall) -> ToolResult {
        let (output, api_tokens) = self.run_tool(&call.tool, &call.args);
        let exec_time = ego_tool_latency(&call.tool)
            .sample(self.seed, &format!("{}({})", call.tool, call.args));
        ToolResult { output, exec_time, api_tokens }
    }

    fn fork(&self) -> Box<dyn ToolExecutionEnvironment> {
        // "To fork a sandbox state, we make a copy of the task's folder."
        Box::new(VideoSandbox {
            seed: self.seed,
            video_loaded: self.video_loaded,
            preprocessed: self.preprocessed,
            running: true,
        })
    }

    fn snapshot(&self) -> SandboxSnapshot {
        let mut bytes = self.seed.to_le_bytes().to_vec();
        bytes.push(self.video_loaded as u8);
        bytes.push(self.preprocessed as u8);
        // Folder copies are fast filesystem operations (Appendix D).
        SandboxSnapshot { bytes, serialize_cost: 0.05, restore_cost: 0.08 }
    }

    fn will_mutate_state(&self, call: &ToolCall) -> bool {
        tool_mutates(&call.tool)
    }

    fn state_fingerprint(&self) -> u64 {
        fnv1a(&self.seed.to_le_bytes())
            ^ ((self.video_loaded as u64) << 1)
            ^ ((self.preprocessed as u64) << 2)
    }
}

/// Factory for video sandboxes.
pub struct VideoFactory;

impl SandboxFactory for VideoFactory {
    fn create(&self, task_seed: u64) -> Box<dyn ToolExecutionEnvironment> {
        let mut sb = VideoSandbox::new(task_seed);
        sb.start();
        Box::new(sb)
    }

    fn restore(&self, snap: &SandboxSnapshot) -> Box<dyn ToolExecutionEnvironment> {
        let mut seed_bytes = [0u8; 8];
        seed_bytes.copy_from_slice(&snap.bytes[..8]);
        let mut sb = VideoSandbox::new(u64::from_le_bytes(seed_bytes));
        sb.video_loaded = snap.bytes[8] != 0;
        sb.preprocessed = snap.bytes[9] != 0;
        sb.running = true;
        Box::new(sb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ready(seed: u64) -> VideoSandbox {
        let mut sb = VideoSandbox::new(seed);
        sb.start();
        sb.execute(&ToolCall::new("load_video", "video_7.mp4"));
        sb.execute(&ToolCall::new("preprocess", ""));
        sb
    }

    #[test]
    fn tools_require_load_and_preprocess() {
        let mut sb = VideoSandbox::new(1);
        sb.start();
        let out = sb
            .execute(&ToolCall::stateless("caption_retrieval", "(0, 10)"))
            .output;
        assert!(out.contains("load_video first"), "{out}");
        sb.execute(&ToolCall::new("load_video", "v.mp4"));
        let out = sb
            .execute(&ToolCall::stateless("caption_retrieval", "(0, 10)"))
            .output;
        assert!(out.contains("preprocess first"), "{out}");
    }

    #[test]
    fn captions_deterministic_per_seed() {
        let mut a = ready(5);
        let mut b = ready(5);
        let call = ToolCall::stateless("caption_retrieval", "(0, 10)");
        assert_eq!(a.execute(&call).output, b.execute(&call).output);
        let mut c = ready(6);
        assert_ne!(a.execute(&call).output, c.execute(&call).output);
    }

    #[test]
    fn caption_retrieval_respects_15_cap() {
        let mut sb = ready(2);
        let out = sb
            .execute(&ToolCall::stateless("caption_retrieval", "(0, 40)"))
            .output;
        assert_eq!(out.lines().count(), 15);
    }

    #[test]
    fn caption_tool_charges_api_tokens() {
        let mut sb = ready(3);
        let r = sb.execute(&ToolCall::stateless("caption_retrieval", "(0, 10)"));
        assert!(r.api_tokens > 0);
        let r2 = sb.execute(&ToolCall::stateless("segment_localization", "cutting"));
        assert_eq!(r2.api_tokens, 0);
    }

    #[test]
    fn statefulness_annotations_match_appendix_d() {
        let sb = VideoSandbox::new(1);
        assert!(sb.will_mutate_state(&ToolCall::new("load_video", "v")));
        assert!(sb.will_mutate_state(&ToolCall::new("preprocess", "")));
        for t in [
            "object_memory_querying",
            "segment_localization",
            "caption_retrieval",
            "visual_question_answering",
        ] {
            assert!(!sb.will_mutate_state(&ToolCall::new(t, "x")), "{t}");
        }
    }

    #[test]
    fn object_memory_is_slowest_tool() {
        let mut sb = ready(4);
        let omq = sb
            .execute(&ToolCall::stateless("object_memory_querying", "how many people"))
            .exec_time;
        let cap = sb
            .execute(&ToolCall::stateless("caption_retrieval", "(0, 5)"))
            .exec_time;
        let load = sb.execute(&ToolCall::new("load_video", "v")).exec_time;
        assert!(omq > cap, "omq {omq} cap {cap}");
        assert!(load < cap, "load {load} cap {cap}");
    }

    #[test]
    fn snapshot_restore_preserves_phase() {
        let sb = ready(9);
        let snap = sb.snapshot();
        let restored = VideoFactory.restore(&snap);
        assert_eq!(restored.state_fingerprint(), sb.state_fingerprint());
    }

    #[test]
    fn fork_independent() {
        let sb = ready(11);
        let mut f = sb.fork();
        assert_eq!(f.state_fingerprint(), sb.state_fingerprint());
        // Forks answer queries identically (same folder copy).
        let call = ToolCall::stateless("visual_question_answering", "('holding?', 5)");
        let out = f.execute(&call).output;
        assert!(out.contains("segment 5"), "{out}");
    }
}
