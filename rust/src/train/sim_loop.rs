//! RL post-training drivers over the sharded cache backend.
//!
//! Two drivers share the [`CacheBackend`] surface:
//!
//! * [`run_workload`] — the virtual-clock DES loop reproducing the paper's
//!   measurement setup: per task, `R` parallel rollouts interleave
//!   reasoning-token generation (charged at the model's tok/s) with tool
//!   calls through the `ToolCallExecutor`. The discrete-event scheduler
//!   interleaves rollouts in virtual time, so cache population order — and
//!   therefore who hits and who misses — emerges from the same dynamics as
//!   on real hardware. Caches persist across epochs (§3.1: the TCG is
//!   "reused across post-training iterations"), producing the rising
//!   hit-rate curves of Figure 5.
//! * [`run_concurrent`] — a real-thread driver: all B·R rollouts of an
//!   epoch execute concurrently on a [`ThreadPool`] against the same
//!   [`ShardedCacheService`], measuring wall-clock throughput rather than
//!   simulated latency (the §4.5 service-concurrency regime).

use std::sync::mpsc;
use std::sync::Arc;

use crate::agent::scripted::Agent;
use crate::cache::{
    CacheBackend, CacheFactory, EvictionPolicy, LpmConfig, ServiceConfig, SessionBackend,
    ShardedCacheService, TaskCache,
};
use crate::client::{ExecutorConfig, ToolCallExecutor};
use crate::sim::EventQueue;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use crate::workloads::WorkloadConfig;

/// One observed tool call (drives Figures 2/11/12/14).
#[derive(Debug, Clone)]
pub struct CallSample {
    pub tool: String,
    pub args: String,
    /// Seconds the rollout waited.
    pub charged: f64,
    pub hit: bool,
    pub epoch: usize,
}

/// Per-rollout accounting (Figures 2/7).
#[derive(Debug, Clone)]
pub struct RolloutMetrics {
    pub task: usize,
    pub rollout: usize,
    pub epoch: usize,
    pub gen_time: f64,
    pub tool_time: f64,
    pub reward: f64,
    pub hits: u64,
    pub misses: u64,
}

impl RolloutMetrics {
    pub fn total(&self) -> f64 {
        self.gen_time + self.tool_time
    }
}

/// Per-(task, epoch) batch accounting (Figures 7b/15).
#[derive(Debug, Clone)]
pub struct BatchMetrics {
    pub task: usize,
    pub epoch: usize,
    /// Virtual seconds until the slowest rollout finished.
    pub batch_time: f64,
    pub longest_rollout: f64,
}

/// Aggregated run output.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub rollouts: Vec<RolloutMetrics>,
    pub batches: Vec<BatchMetrics>,
    pub calls: Vec<CallSample>,
    /// (epoch, hit_rate) series — Figure 5.
    pub epoch_hit_rates: Vec<(usize, f64)>,
    /// (epoch, mean_reward) series — Figure 6.
    pub epoch_rewards: Vec<(usize, f64)>,
    /// API tokens consumed by executed calls (EgoSchema §4.3).
    pub api_tokens_spent: u64,
    /// API tokens that cache hits avoided re-spending.
    pub api_tokens_saved: u64,
}

impl RunMetrics {
    pub fn overall_hit_rate(&self) -> f64 {
        let (h, m) = self
            .rollouts
            .iter()
            .fold((0u64, 0u64), |(h, m), r| (h + r.hits, m + r.misses));
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    pub fn median_call_time(&self) -> f64 {
        let mut s = crate::util::hist::Samples::new();
        for c in &self.calls {
            s.add(c.charged);
        }
        s.median()
    }
}

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// TVCACHE on or off (the paper's with/without comparison).
    pub cached: bool,
    /// Override the number of tasks (Table 1 defaults are large; benches
    /// subsample for wall-clock reasons and note it in EXPERIMENTS.md).
    pub n_tasks: usize,
    pub epochs: usize,
    pub rollouts: usize,
    pub seed: u64,
    pub lpm: LpmConfig,
    /// Sandbox budget per task (Figure 8b sensitivity).
    pub max_snapshots: usize,
    /// Cache-service shard count (§4.5; tasks hash across shards).
    pub shards: usize,
    /// Stateful lookup cursors: executors send only the delta call per
    /// lookup (O(1) per tool call). `false` forces the legacy full-prefix
    /// path (the fig10 A/B baseline).
    pub use_cursor: bool,
    /// Turn-level batching: cursor ops ship as single `/session_turn`
    /// frames. `false` forces the per-call cursor endpoints; hit/miss
    /// decisions are identical either way (asserted by a DES test).
    pub batch_turns: bool,
}

impl SimOptions {
    pub fn from_config(cfg: &WorkloadConfig, n_tasks: usize, cached: bool) -> SimOptions {
        SimOptions {
            cached,
            n_tasks: n_tasks.min(cfg.n_tasks),
            epochs: cfg.epochs,
            rollouts: cfg.rollouts,
            seed: 0x7CAC4E,
            lpm: LpmConfig::default(),
            max_snapshots: 64,
            shards: 4,
            use_cursor: true,
            batch_turns: true,
        }
    }
}

/// Build the sharded backend whose per-task caches carry the workload's
/// policies; both drivers go through this.
fn sharded_backend(
    cfg: &WorkloadConfig,
    lpm: LpmConfig,
    max_snapshots: usize,
    shards: usize,
) -> Arc<ShardedCacheService> {
    sharded_backend_with(
        cfg,
        lpm,
        max_snapshots,
        ServiceConfig { shards, ..Default::default() },
    )
}

/// As [`sharded_backend`] but with the full snapshot-lifecycle
/// [`ServiceConfig`] (byte budgets, spill tier, background workers).
fn sharded_backend_with(
    cfg: &WorkloadConfig,
    lpm: LpmConfig,
    max_snapshots: usize,
    svc_cfg: ServiceConfig,
) -> Arc<ShardedCacheService> {
    let snapshot_policy = cfg.snapshot_policy();
    let factory: CacheFactory = Arc::new(move || {
        TaskCache::new(
            lpm,
            snapshot_policy,
            EvictionPolicy { max_snapshots, ..Default::default() },
        )
    });
    Arc::new(
        ShardedCacheService::with_config(svc_cfg, factory)
            .expect("spill directory must be creatable"),
    )
}

/// Rollout process state inside the DES.
struct RolloutProc {
    agent: crate::agent::ScriptedAgent,
    executor: ToolCallExecutor,
    trajectory: Vec<(crate::cache::ToolCall, String)>,
    gen_time: f64,
    tool_time: f64,
    rng: Rng,
    tokens_per_sec: f64,
    tokens_per_step: f64,
    done: bool,
}

/// Run one workload end-to-end under the simulator.
pub fn run_workload(cfg: &WorkloadConfig, opts: &SimOptions) -> RunMetrics {
    // One sharded cache service for the whole run; per-task caches are
    // created on first touch and persist across epochs.
    let backend = sharded_backend(cfg, opts.lpm, opts.max_snapshots, opts.shards);
    run_workload_on(cfg, opts, backend as Arc<dyn SessionBackend>)
}

/// As [`run_workload`] but against a caller-supplied backend — the
/// fault-injection tests wrap the sharded service in flaky decorators and
/// assert the rollout rewards still match a cacheless run.
pub fn run_workload_on(
    cfg: &WorkloadConfig,
    opts: &SimOptions,
    backend: Arc<dyn SessionBackend>,
) -> RunMetrics {
    let mut metrics = RunMetrics::default();
    let factory = cfg.factory();

    for epoch in 0..opts.epochs {
        let mut epoch_hits = 0u64;
        let mut epoch_misses = 0u64;
        let mut epoch_reward = 0.0;
        let mut epoch_rollouts = 0usize;

        for task in 0..opts.n_tasks {
            let task_seed = opts.seed ^ (task as u64).wrapping_mul(0x9E37_79B9);
            let task_name = format!("task-{task}");

            // Build the R parallel rollout processes.
            let mut procs: Vec<RolloutProc> = (0..opts.rollouts)
                .map(|r| {
                    let rollout_seed = (epoch * opts.rollouts + r) as u64;
                    let exec_cfg = if opts.cached {
                        ExecutorConfig {
                            stateful_filtering: opts.lpm.stateful_filtering,
                            use_cursor: opts.use_cursor,
                            batch_turns: opts.batch_turns,
                            ..ExecutorConfig::default()
                        }
                    } else {
                        ExecutorConfig {
                            // B·R containers created concurrently at step
                            // start contend in the baseline manager
                            // (Figure 13): scale the cold start/stop cost.
                            cold_start_factor: (opts.rollouts as f64 / 2.0).max(1.0),
                            ..ExecutorConfig::cacheless()
                        }
                    };
                    RolloutProc {
                        agent: cfg.agent(task_seed, rollout_seed),
                        executor: ToolCallExecutor::new(
                            Arc::clone(&backend),
                            task_name.clone(),
                            Arc::clone(&factory),
                            task_seed,
                            exec_cfg,
                        ),
                        trajectory: Vec::new(),
                        gen_time: 0.0,
                        tool_time: 0.0,
                        rng: Rng::new(task_seed ^ rollout_seed.rotate_left(32) ^ 0xABCD),
                        tokens_per_sec: cfg.tokens_per_sec,
                        tokens_per_step: cfg.tokens_per_step,
                        done: false,
                    }
                })
                .collect();

            // Drive them through the DES.
            let mut queue: EventQueue<usize> = EventQueue::new();
            let mut finish_times = vec![0.0f64; opts.rollouts];
            for r in 0..opts.rollouts {
                // Stagger starts slightly: rollouts never begin in perfect
                // lockstep on real infrastructure.
                queue.schedule(procs[r].rng.range_f64(0.0, 0.25), r);
            }
            while let Some(r) = queue.pop() {
                let now = queue.now();
                let p = &mut procs[r];
                if p.done {
                    continue;
                }
                match p.agent.next_call(&p.trajectory) {
                    Some(call) => {
                        // Reasoning-token generation preceding the call.
                        let tokens = p.tokens_per_step * p.rng.lognormal(0.0, 0.35);
                        let gen = tokens / p.tokens_per_sec;
                        p.gen_time += gen;
                        let outcome = p.executor.call(call.clone());
                        p.tool_time += outcome.charged;
                        p.trajectory.push((call.clone(), outcome.result.output.clone()));
                        if opts.cached && outcome.hit {
                            metrics.api_tokens_saved += outcome.result.api_tokens;
                        } else {
                            metrics.api_tokens_spent += outcome.result.api_tokens;
                        }
                        metrics.calls.push(CallSample {
                            tool: call.tool,
                            args: call.args,
                            charged: outcome.charged,
                            hit: outcome.hit,
                            epoch,
                        });
                        queue.schedule(gen + outcome.charged, r);
                    }
                    None => {
                        p.tool_time += p.executor.finish();
                        p.done = true;
                        finish_times[r] = now;
                    }
                }
            }

            // Collect metrics for this (task, epoch).
            let mut longest = 0.0f64;
            for (r, p) in procs.into_iter().enumerate() {
                let reward =
                    cfg.reward(task_seed, &p.trajectory, &p.agent.final_answer());
                epoch_hits += p.executor.hits;
                epoch_misses += p.executor.misses;
                epoch_reward += reward;
                epoch_rollouts += 1;
                longest = longest.max(p.gen_time + p.tool_time);
                metrics.rollouts.push(RolloutMetrics {
                    task,
                    rollout: r,
                    epoch,
                    gen_time: p.gen_time,
                    tool_time: p.tool_time,
                    reward,
                    hits: p.executor.hits,
                    misses: p.executor.misses,
                });
            }
            metrics.batches.push(BatchMetrics {
                task,
                epoch,
                batch_time: finish_times.iter().cloned().fold(0.0, f64::max),
                longest_rollout: longest,
            });
        }

        let hit_rate = if epoch_hits + epoch_misses == 0 {
            0.0
        } else {
            epoch_hits as f64 / (epoch_hits + epoch_misses) as f64
        };
        metrics.epoch_hit_rates.push((epoch, hit_rate));
        metrics
            .epoch_rewards
            .push((epoch, epoch_reward / epoch_rollouts.max(1) as f64));
    }
    metrics
}

/// Options for the real-thread concurrent driver.
#[derive(Debug, Clone)]
pub struct ConcurrentOptions {
    /// TVCACHE on or off (the paper's with/without comparison; `false`
    /// runs every rollout through the plain direct-execution path).
    pub cached: bool,
    pub n_tasks: usize,
    pub rollouts: usize,
    pub epochs: usize,
    /// Worker threads driving rollouts (the B·R concurrency of §4.5).
    pub threads: usize,
    /// Cache-service shard count.
    pub shards: usize,
    pub seed: u64,
    pub lpm: LpmConfig,
    pub max_snapshots: usize,
    /// Resident-byte budget per shard store, enforced by the background
    /// eviction workers (`None` = unbounded).
    pub shard_byte_budget: Option<u64>,
    /// Spill directory: over-budget snapshots demote to disk instead of
    /// being destroyed.
    pub spill_dir: Option<String>,
    /// Warm-start: load a persisted cache state before epoch 0, so the
    /// run starts with the previous run's TCGs + spilled snapshots.
    pub warm_start_from: Option<String>,
    /// Persist the cache state after the final epoch (warm-start source
    /// for the next run).
    pub persist_to: Option<String>,
    /// Stateful lookup cursors (see [`SimOptions::use_cursor`]).
    pub use_cursor: bool,
    /// Turn-level batching (see [`SimOptions::batch_turns`]).
    pub batch_turns: bool,
    /// Path to a `cluster.json`: run against a [`ClusterRouter`] over the
    /// mapped replication groups (which must already be serving) instead
    /// of building an in-process backend. `shards`/budget/spill options
    /// describe the in-process backend and are ignored in cluster mode;
    /// warm-start/persist fan out per group through the router.
    pub cluster_map: Option<String>,
}

impl ConcurrentOptions {
    pub fn from_config(cfg: &WorkloadConfig, n_tasks: usize) -> ConcurrentOptions {
        ConcurrentOptions {
            cached: true,
            n_tasks: n_tasks.min(cfg.n_tasks),
            rollouts: cfg.rollouts,
            epochs: cfg.epochs,
            threads: 8,
            shards: 4,
            seed: 0x7CAC4E,
            lpm: LpmConfig::default(),
            max_snapshots: 64,
            shard_byte_budget: None,
            spill_dir: None,
            warm_start_from: None,
            persist_to: None,
            use_cursor: true,
            batch_turns: true,
            cluster_map: None,
        }
    }
}

/// What the concurrent driver measured.
#[derive(Debug, Clone, Default)]
pub struct ConcurrentReport {
    pub rollouts_run: usize,
    pub hits: u64,
    pub misses: u64,
    /// Summed simulated tool-wait seconds (comparable to `RunMetrics`).
    pub tool_time: f64,
    /// Real wall-clock seconds for the whole run.
    pub wall_secs: f64,
    /// (epoch, hit_rate) series, as in Figure 5.
    pub epoch_hit_rates: Vec<(usize, f64)>,
    /// Per-rollout rewards in deterministic (epoch, task, rollout) order —
    /// thread scheduling never reorders them, so two runs with identical
    /// seeds are comparable element-wise (the Figure 6 invariant under
    /// fault injection).
    pub rewards: Vec<f64>,
}

impl ConcurrentReport {
    pub fn overall_hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }

    pub fn calls_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            (self.hits + self.misses) as f64 / self.wall_secs
        }
    }
}

/// Drive all B·R rollouts of each epoch *concurrently* (real threads, real
/// contention) against one [`ShardedCacheService`]. Epochs are barriers —
/// epoch `e+1` starts only when every rollout of epoch `e` finished — so
/// the cross-epoch hit-rate dynamics match the DES driver; within an epoch,
/// rollout interleaving is whatever the scheduler does, exactly as on real
/// training infrastructure.
pub fn run_concurrent(cfg: &WorkloadConfig, opts: &ConcurrentOptions) -> ConcurrentReport {
    if let Some(path) = &opts.cluster_map {
        // Cluster mode: route by task across already-serving replication
        // groups instead of building an in-process backend.
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("cluster map {path} unreadable: {e}"));
        let map = crate::cluster::ClusterMap::parse(&text)
            .unwrap_or_else(|e| panic!("cluster map {path}: {e}"));
        let router = Arc::new(crate::cluster::ClusterRouter::connect(
            map,
            crate::client::BindingConfig::default(),
        ));
        if let Some(dir) = &opts.warm_start_from {
            assert!(
                router.warm_start(dir),
                "warm-start requested but {dir} did not load on every group"
            );
        }
        let report = run_concurrent_on(cfg, opts, Arc::clone(&router) as Arc<dyn SessionBackend>);
        if let Some(dir) = &opts.persist_to {
            assert!(
                router.persist(dir),
                "persist requested but {dir} was not writable on every group"
            );
        }
        return report;
    }
    let backend = sharded_backend_with(
        cfg,
        opts.lpm,
        opts.max_snapshots,
        ServiceConfig {
            shards: opts.shards,
            shard_byte_budget: opts.shard_byte_budget,
            global_byte_budget: None,
            spill_dir: opts.spill_dir.clone().map(std::path::PathBuf::from),
            background: opts.shard_byte_budget.is_some(),
            ..Default::default()
        },
    );
    if let Some(dir) = &opts.warm_start_from {
        assert!(
            backend.warm_start(dir),
            "warm-start requested but {dir} did not load"
        );
    }
    let report =
        run_concurrent_on(cfg, opts, Arc::clone(&backend) as Arc<dyn SessionBackend>);
    if let Some(dir) = &opts.persist_to {
        // Let the background eviction workers finish any in-flight spill
        // before persisting, so the manifest has a single writer.
        backend.quiesce();
        assert!(backend.persist(dir), "persist requested but {dir} was not writable");
    }
    report
}

/// As [`run_concurrent`] but against a caller-supplied backend (a
/// [`RemoteBinding`](crate::client::RemoteBinding) to a killable server,
/// a fault-wrapped service, …). Warm-start/persist stay with
/// [`run_concurrent`], which owns the concrete sharded service.
pub fn run_concurrent_on(
    cfg: &WorkloadConfig,
    opts: &ConcurrentOptions,
    backend: Arc<dyn SessionBackend>,
) -> ConcurrentReport {
    let factory = cfg.factory();
    let pool = ThreadPool::new(opts.threads);
    let mut report = ConcurrentReport::default();
    let t0 = std::time::Instant::now();

    for epoch in 0..opts.epochs {
        let (tx, rx) = mpsc::channel::<(usize, usize, u64, u64, f64, f64)>();
        let mut scheduled = 0usize;
        for task in 0..opts.n_tasks {
            let task_seed = opts.seed ^ (task as u64).wrapping_mul(0x9E37_79B9);
            for r in 0..opts.rollouts {
                let rollout_seed = (epoch * opts.rollouts + r) as u64;
                let mut agent = cfg.agent(task_seed, rollout_seed);
                let backend = Arc::clone(&backend);
                let factory = Arc::clone(&factory);
                let task_name = format!("task-{task}");
                let exec_cfg = if opts.cached {
                    ExecutorConfig {
                        stateful_filtering: opts.lpm.stateful_filtering,
                        use_cursor: opts.use_cursor,
                        batch_turns: opts.batch_turns,
                        ..ExecutorConfig::default()
                    }
                } else {
                    ExecutorConfig::cacheless()
                };
                let tx = tx.clone();
                let reward_cfg = cfg.clone();
                scheduled += 1;
                pool.execute(move || {
                    let mut exec = ToolCallExecutor::new(
                        backend, task_name, factory, task_seed, exec_cfg,
                    );
                    let mut trajectory = Vec::new();
                    let mut tool_time = 0.0;
                    while let Some(call) = agent.next_call(&trajectory) {
                        let outcome = exec.call(call.clone());
                        tool_time += outcome.charged;
                        trajectory.push((call, outcome.result.output));
                    }
                    tool_time += exec.finish();
                    let reward =
                        reward_cfg.reward(task_seed, &trajectory, &agent.final_answer());
                    let _ = tx.send((task, r, exec.hits, exec.misses, tool_time, reward));
                });
            }
        }
        drop(tx);
        // Epoch barrier: wait for every rollout before the next epoch.
        let mut epoch_hits = 0u64;
        let mut epoch_misses = 0u64;
        let mut epoch_rewards: Vec<(usize, usize, f64)> = Vec::with_capacity(scheduled);
        for (task, rollout, hits, misses, tool_time, reward) in rx.iter() {
            epoch_hits += hits;
            epoch_misses += misses;
            report.tool_time += tool_time;
            report.rollouts_run += 1;
            epoch_rewards.push((task, rollout, reward));
        }
        assert_eq!(
            report.rollouts_run,
            (epoch + 1) * scheduled,
            "a rollout thread died without reporting"
        );
        // Arrival order is whatever the scheduler did; re-sort so the
        // rewards vector is deterministic and comparable across runs.
        epoch_rewards.sort_by_key(|&(task, rollout, _)| (task, rollout));
        report.rewards.extend(epoch_rewards.into_iter().map(|(_, _, rw)| rw));
        report.hits += epoch_hits;
        report.misses += epoch_misses;
        let denom = (epoch_hits + epoch_misses).max(1);
        report
            .epoch_hit_rates
            .push((epoch, epoch_hits as f64 / denom as f64));
    }
    report.wall_secs = t0.elapsed().as_secs_f64();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{Workload, WorkloadConfig};

    fn quick_opts(cfg: &WorkloadConfig, cached: bool) -> SimOptions {
        let mut o = SimOptions::from_config(cfg, 4, cached);
        o.epochs = 4;
        o
    }

    #[test]
    fn cached_run_hits_and_uncached_never_does() {
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let cached = run_workload(&cfg, &quick_opts(&cfg, true));
        let uncached = run_workload(&cfg, &quick_opts(&cfg, false));
        assert!(cached.overall_hit_rate() > 0.05, "{}", cached.overall_hit_rate());
        assert_eq!(uncached.overall_hit_rate(), 0.0);
    }

    #[test]
    fn hit_rate_rises_over_epochs() {
        let cfg = WorkloadConfig::config_for(Workload::SkyRlSql);
        let m = run_workload(&cfg, &quick_opts(&cfg, true));
        let first = m.epoch_hit_rates[0].1;
        let last = m.epoch_hit_rates.last().unwrap().1;
        assert!(last > first, "hit rate should rise: {first} -> {last}");
    }

    #[test]
    fn rewards_match_with_and_without_cache() {
        // Figure 6's claim: exact caching must not change reward statistics.
        // Identical seeds ⇒ identical agent plans ⇒ identical rewards.
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let a = run_workload(&cfg, &quick_opts(&cfg, true));
        let b = run_workload(&cfg, &quick_opts(&cfg, false));
        let ra: Vec<f64> = a.rollouts.iter().map(|r| r.reward).collect();
        let rb: Vec<f64> = b.rollouts.iter().map(|r| r.reward).collect();
        assert_eq!(ra, rb, "caching changed rewards");
    }

    #[test]
    fn cache_reduces_tool_time() {
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let cached = run_workload(&cfg, &quick_opts(&cfg, true));
        let uncached = run_workload(&cfg, &quick_opts(&cfg, false));
        let t_cached: f64 = cached.rollouts.iter().map(|r| r.tool_time).sum();
        let t_uncached: f64 = uncached.rollouts.iter().map(|r| r.tool_time).sum();
        assert!(
            t_cached < t_uncached * 0.8,
            "cached {t_cached:.1}s vs uncached {t_uncached:.1}s"
        );
    }

    #[test]
    fn gen_time_positive_and_batches_recorded() {
        let cfg = WorkloadConfig::config_for(Workload::EgoSchema);
        let m = run_workload(&cfg, &quick_opts(&cfg, true));
        assert!(m.rollouts.iter().all(|r| r.gen_time > 0.0));
        assert_eq!(m.batches.len(), 4 * 4); // tasks × epochs
        assert!(m.batches.iter().all(|b| b.batch_time > 0.0));
    }

    #[test]
    fn ego_run_saves_api_tokens() {
        let cfg = WorkloadConfig::config_for(Workload::EgoSchema);
        let m = run_workload(&cfg, &quick_opts(&cfg, true));
        assert!(m.api_tokens_saved > 0, "hits should save API tokens");
    }

    #[test]
    fn concurrent_driver_hits_and_converges() {
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let mut opts = ConcurrentOptions::from_config(&cfg, 4);
        opts.epochs = 3;
        opts.threads = 8;
        opts.shards = 4;
        let report = run_concurrent(&cfg, &opts);
        assert_eq!(report.rollouts_run, 4 * opts.rollouts * 3);
        assert!(report.hits > 0, "warm epochs must hit");
        let first = report.epoch_hit_rates[0].1;
        let last = report.epoch_hit_rates.last().unwrap().1;
        assert!(
            last >= first,
            "hit rate should not degrade across epochs: {first} -> {last}"
        );
        assert!(report.wall_secs > 0.0);
        assert!(report.calls_per_sec() > 0.0);
    }

    #[test]
    fn concurrent_driver_matches_des_hit_band() {
        // Real-thread interleaving changes *which* rollout populates the
        // cache first, but the overall hit rate must land in the same band
        // as the virtual-clock driver (same agents, same cache semantics).
        let cfg = WorkloadConfig::config_for(Workload::SkyRlSql);
        let des = run_workload(&cfg, &quick_opts(&cfg, true));
        let mut copts = ConcurrentOptions::from_config(&cfg, 4);
        copts.epochs = 4;
        let conc = run_concurrent(&cfg, &copts);
        let a = des.overall_hit_rate();
        let b = conc.overall_hit_rate();
        assert!(
            (a - b).abs() < 0.25,
            "drivers diverged: DES {a:.2} vs concurrent {b:.2}"
        );
    }

    #[test]
    fn concurrent_warm_start_resumes_hit_rates() {
        // The warm-start acceptance shape: a new run loading the previous
        // run's persisted cache opens at (at least) the hit rate the cold
        // run only reached by its final epoch.
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let dir = std::env::temp_dir()
            .join(format!("tvcache-simloop-warm-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().into_owned();

        let mut cold = ConcurrentOptions::from_config(&cfg, 3);
        cold.epochs = 3;
        cold.persist_to = Some(dir_s.clone());
        let cold_rep = run_concurrent(&cfg, &cold);

        let mut warm = ConcurrentOptions::from_config(&cfg, 3);
        warm.epochs = 1;
        warm.warm_start_from = Some(dir_s);
        let warm_rep = run_concurrent(&cfg, &warm);

        let cold_final = cold_rep.epoch_hit_rates.last().unwrap().1;
        let warm_first = warm_rep.epoch_hit_rates[0].1;
        assert!(
            warm_first >= cold_final,
            "warm epoch 0 ({warm_first:.2}) below cold final epoch ({cold_final:.2})"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cursor_and_legacy_paths_agree() {
        // The DES is deterministic given the seed, so the incremental
        // cursor path and the legacy full-prefix path must make *identical*
        // hit/miss decisions — any divergence is a cursor-semantics bug.
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let cursor = run_workload(&cfg, &quick_opts(&cfg, true));
        let mut legacy_opts = quick_opts(&cfg, true);
        legacy_opts.use_cursor = false;
        let legacy = run_workload(&cfg, &legacy_opts);
        assert_eq!(cursor.overall_hit_rate(), legacy.overall_hit_rate());
        assert_eq!(cursor.epoch_hit_rates, legacy.epoch_hit_rates);
        let rc: Vec<f64> = cursor.rollouts.iter().map(|r| r.reward).collect();
        let rl: Vec<f64> = legacy.rollouts.iter().map(|r| r.reward).collect();
        assert_eq!(rc, rl, "cursor path changed rewards");
    }

    #[test]
    fn batched_and_unbatched_turns_make_identical_decisions() {
        // The acceptance DES test: turn-level batching is a wire-shape
        // change only. The virtual-clock driver is deterministic given the
        // seed, so the batched (`/session_turn`) and unbatched (per-call
        // cursor) paths must make *identical* per-call hit/miss decisions
        // — any divergence is a batching-semantics bug.
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let batched = run_workload(&cfg, &quick_opts(&cfg, true));
        let mut unbatched_opts = quick_opts(&cfg, true);
        unbatched_opts.batch_turns = false;
        let unbatched = run_workload(&cfg, &unbatched_opts);
        let db: Vec<bool> = batched.calls.iter().map(|c| c.hit).collect();
        let du: Vec<bool> = unbatched.calls.iter().map(|c| c.hit).collect();
        assert_eq!(db, du, "batching changed a per-call hit/miss decision");
        assert_eq!(batched.epoch_hit_rates, unbatched.epoch_hit_rates);
        let rb: Vec<f64> = batched.rollouts.iter().map(|r| r.reward).collect();
        let ru: Vec<f64> = unbatched.rollouts.iter().map(|r| r.reward).collect();
        assert_eq!(rb, ru, "batching changed rewards");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let a = run_workload(&cfg, &quick_opts(&cfg, true));
        let b = run_workload(&cfg, &quick_opts(&cfg, true));
        assert_eq!(a.overall_hit_rate(), b.overall_hit_rate());
        assert_eq!(a.median_call_time(), b.median_call_time());
    }
}
