//! Post-training drivers.
//!
//! * [`sim_loop`] — the discrete-event simulated RL post-training loop used
//!   by the paper-figure benches: scripted agents, paper-calibrated
//!   latencies, virtual time.
//! * [`grpo`] — group-relative advantage computation (GRPO, Appendix C) and
//!   the trajectory→tensor packing consumed by the PJRT train-step artifact
//!   (the real policy-learning loop in `examples/e2e_terminal_rl.rs`).

pub mod grpo;
pub mod sim_loop;

pub use grpo::{advantages, pack_batch, PackedBatch};
pub use sim_loop::{
    run_concurrent, run_concurrent_on, run_workload, run_workload_on, BatchMetrics,
    CallSample, ConcurrentOptions, ConcurrentReport, RolloutMetrics, RunMetrics,
    SimOptions,
};
