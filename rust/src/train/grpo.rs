//! GRPO: group-relative advantage computation and trajectory packing for
//! the PJRT train-step artifact (Layer 2's `agent_train.hlo.txt`).
//!
//! GRPO (Shao et al., 2024) normalizes rewards within the group of rollouts
//! generated for the same prompt: `A_i = (r_i - mean(r)) / (std(r) + ε)`.
//! One policy-gradient step per batch makes the importance ratio 1, so the
//! REINFORCE-style loss in `python/compile/model.py::pg_loss` is exact.

/// Group-relative advantages.
pub fn advantages(rewards: &[f64]) -> Vec<f64> {
    let n = rewards.len();
    if n == 0 {
        return Vec::new();
    }
    let mean = rewards.iter().sum::<f64>() / n as f64;
    let var = rewards.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / n as f64;
    let std = var.sqrt();
    rewards.iter().map(|r| (r - mean) / (std + 1e-6)).collect()
}

/// A token batch ready for the train-step artifact.
#[derive(Debug, Clone)]
pub struct PackedBatch {
    /// `[batch * seq]` row-major token ids (BOS + actions, padded with 0).
    pub tokens: Vec<i32>,
    /// `[batch * seq]` loss mask: position `t` gates prediction of `t+1`.
    pub mask: Vec<f32>,
    /// `[batch]` per-rollout advantages.
    pub adv: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

/// Pack rollout token sequences (each starting with BOS) into fixed-shape
/// tensors. Sequences longer than `seq` are truncated; the mask covers
/// positions `0..len-1` (each predicts the next emitted token).
pub fn pack_batch(rollouts: &[Vec<i32>], advantages_: &[f64], batch: usize, seq: usize) -> PackedBatch {
    assert_eq!(rollouts.len(), advantages_.len());
    let mut tokens = vec![0i32; batch * seq];
    let mut mask = vec![0f32; batch * seq];
    let mut adv = vec![0f32; batch];
    for (b, (toks, a)) in rollouts.iter().zip(advantages_).enumerate().take(batch) {
        let len = toks.len().min(seq);
        tokens[b * seq..b * seq + len].copy_from_slice(&toks[..len]);
        // Position t predicts token t+1 ⇒ mask positions 0..len-1.
        for t in 0..len.saturating_sub(1) {
            mask[b * seq + t] = 1.0;
        }
        adv[b] = *a as f32;
    }
    PackedBatch { tokens, mask, adv, batch, seq }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advantages_zero_mean() {
        let a = advantages(&[1.0, 0.0, 0.0, 1.0]);
        let sum: f64 = a.iter().sum();
        assert!(sum.abs() < 1e-9);
        assert!(a[0] > 0.0 && a[1] < 0.0);
        assert_eq!(a[0], a[3]);
    }

    #[test]
    fn advantages_uniform_rewards_are_zero() {
        // All-same rewards give zero signal (the GRPO degenerate case).
        let a = advantages(&[1.0, 1.0, 1.0]);
        assert!(a.iter().all(|x| x.abs() < 1e-3), "{a:?}");
    }

    #[test]
    fn advantages_unit_scale() {
        let a = advantages(&[2.0, 0.0]);
        assert!((a[0] - 1.0).abs() < 1e-3, "{a:?}");
        assert!((a[1] + 1.0).abs() < 1e-3);
    }

    #[test]
    fn pack_shapes_and_padding() {
        let rollouts = vec![vec![0, 7, 8, 1], vec![0, 9, 1]];
        let adv = advantages(&[1.0, 0.0]);
        let p = pack_batch(&rollouts, &adv, 4, 6);
        assert_eq!(p.tokens.len(), 24);
        assert_eq!(p.mask.len(), 24);
        assert_eq!(p.adv.len(), 4);
        // Rollout 0: tokens 0,7,8,1 then padding.
        assert_eq!(&p.tokens[0..6], &[0, 7, 8, 1, 0, 0]);
        // Mask covers positions 0..3 (predicting 7, 8, 1).
        assert_eq!(&p.mask[0..6], &[1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
        // Unused batch rows fully masked out.
        assert!(p.mask[12..].iter().all(|&m| m == 0.0));
        assert_eq!(p.adv[2], 0.0);
    }

    #[test]
    fn pack_truncates_long_sequences() {
        let rollouts = vec![vec![0; 100]];
        let p = pack_batch(&rollouts, &[1.0], 1, 8);
        assert_eq!(p.tokens.len(), 8);
        assert_eq!(p.mask.iter().filter(|&&m| m > 0.0).count(), 7);
    }
}
