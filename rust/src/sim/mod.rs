//! Simulation substrate: virtual time + a discrete-event scheduler.
//!
//! The paper's workload experiments run hundreds of rollouts whose tool
//! calls take seconds to minutes on 128-core servers. On this testbed we
//! replay those experiments under a virtual clock: tool latencies are drawn
//! from paper-calibrated distributions and *advance simulated time* instead
//! of sleeping, so a full post-training run regenerates in milliseconds while
//! preserving the interleaving-dependent cache dynamics (who populates the
//! TCG first, which parallel rollout hits, when eviction fires).

pub mod clock;
pub mod des;

pub use clock::{Clock, RealClock, SimClock};
pub use des::{EventQueue, ProcessOutcome};
