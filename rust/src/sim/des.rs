//! Discrete-event scheduler for simulating parallel rollouts.
//!
//! Each "process" (a rollout, a background fork worker, …) is advanced by
//! callbacks at scheduled virtual times. The queue pops events in time order
//! — ties broken by sequence number for determinism — and the process decides
//! its next wake-up. This reproduces the paper's concurrency effects (e.g.
//! rollout 2's `t1` call *after* rollout 1 populated the TCG hits; before,
//! it misses) without threads.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What a process does when its event fires.
pub enum ProcessOutcome {
    /// Schedule the same process again after `dt` (seconds of virtual time).
    Continue { dt: f64 },
    /// The process is finished.
    Done,
}

struct Event<P> {
    time_ns: u64,
    seq: u64,
    process: P,
}

impl<P> PartialEq for Event<P> {
    fn eq(&self, other: &Self) -> bool {
        self.time_ns == other.time_ns && self.seq == other.seq
    }
}
impl<P> Eq for Event<P> {}
impl<P> PartialOrd for Event<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<P> Ord for Event<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time_ns
            .cmp(&self.time_ns)
            .then(other.seq.cmp(&self.seq))
    }
}

/// An event queue over process handles of type `P`.
pub struct EventQueue<P> {
    heap: BinaryHeap<Event<P>>,
    seq: u64,
    now_ns: u64,
}

impl<P> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now_ns: 0 }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now_ns as f64 * 1e-9
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `process` to run `dt` seconds from now.
    pub fn schedule(&mut self, dt: f64, process: P) {
        let t = self.now_ns + (dt.max(0.0) * 1e9) as u64;
        self.seq += 1;
        self.heap.push(Event { time_ns: t, seq: self.seq, process });
    }

    /// Pop the next event, advancing `now`. Returns the process handle.
    pub fn pop(&mut self) -> Option<P> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time_ns >= self.now_ns, "time went backwards");
        self.now_ns = ev.time_ns;
        Some(ev.process)
    }

    /// Drive to completion: `step(process, now) -> ProcessOutcome`.
    pub fn run<F: FnMut(P, f64) -> ProcessOutcome>(&mut self, mut step: F)
    where
        P: Clone,
    {
        while let Some(p) = self.pop() {
            match step(p.clone(), self.now()) {
                ProcessOutcome::Continue { dt } => self.schedule(dt, p),
                ProcessOutcome::Done => {}
            }
        }
    }
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop(), Some("a"));
        assert!((q.now() - 1.0).abs() < 1e-9);
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), Some("c"));
        assert!((q.now() - 3.0).abs() < 1e-9);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_broken_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!((q.pop(), q.pop(), q.pop()), (Some(1), Some(2), Some(3)));
    }

    #[test]
    fn relative_scheduling_compounds() {
        let mut q = EventQueue::new();
        q.schedule(1.0, ());
        assert!(q.pop().is_some());
        q.schedule(0.5, ()); // now + 0.5 = 1.5
        assert!(q.pop().is_some());
        assert!((q.now() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn run_drives_processes_to_completion() {
        #[derive(Clone)]
        struct P {
            id: usize,
        }
        let mut q = EventQueue::new();
        for id in 0..3 {
            q.schedule(id as f64 * 0.1, P { id });
        }
        let mut fire_counts = [0usize; 3];
        q.run(|p, _now| {
            fire_counts[p.id] += 1;
            if fire_counts[p.id] < 5 {
                ProcessOutcome::Continue { dt: 1.0 }
            } else {
                ProcessOutcome::Done
            }
        });
        assert_eq!(fire_counts, [5, 5, 5]);
    }

    #[test]
    fn interleaving_matches_virtual_time() {
        // Two processes with different periods must interleave by timestamps.
        let mut q = EventQueue::new();
        q.schedule(0.0, "fast");
        q.schedule(0.0, "slow");
        let mut order = Vec::new();
        let mut fast_count = 0;
        let mut slow_count = 0;
        q.run(|p, now| {
            order.push((p, (now * 10.0).round() as i64));
            match p {
                "fast" => {
                    fast_count += 1;
                    if fast_count < 4 {
                        ProcessOutcome::Continue { dt: 0.1 }
                    } else {
                        ProcessOutcome::Done
                    }
                }
                _ => {
                    slow_count += 1;
                    if slow_count < 2 {
                        ProcessOutcome::Continue { dt: 0.25 }
                    } else {
                        ProcessOutcome::Done
                    }
                }
            }
        });
        // fast fires at 0, .1, .2, .3 ; slow at 0, .25
        let times: Vec<i64> = order.iter().map(|(_, t)| *t).collect();
        let mut sorted = times.clone();
        sorted.sort();
        assert_eq!(times, sorted, "events fired out of time order: {order:?}");
    }
}
