//! Virtual/real time behind one trait.
//!
//! All latency-sensitive code paths take a `&dyn Clock`; experiments choose
//! [`SimClock`] (time advances only via `advance`) while the server
//! microbenchmarks (Figure 8) use [`RealClock`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Seconds since an arbitrary epoch.
pub trait Clock: Send + Sync {
    fn now(&self) -> f64;
    /// Advance time by `dt` seconds (sleeps on a real clock).
    fn advance(&self, dt: f64);
    /// True if advancing is free (virtual time).
    fn is_virtual(&self) -> bool;
}

/// Wall-clock time; `advance` sleeps.
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { start: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn advance(&self, dt: f64) {
        if dt > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(dt));
        }
    }

    fn is_virtual(&self) -> bool {
        false
    }
}

/// Virtual time stored as integer nanoseconds for atomic, monotonic updates.
pub struct SimClock {
    nanos: AtomicU64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { nanos: AtomicU64::new(0) }
    }

    /// Set absolute time (used by the DES loop when dequeuing events).
    pub fn set(&self, t: f64) {
        let n = (t.max(0.0) * 1e9) as u64;
        // Monotonic: never move backwards.
        self.nanos.fetch_max(n, Ordering::SeqCst);
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 * 1e-9
    }

    fn advance(&self, dt: f64) {
        let n = (dt.max(0.0) * 1e9) as u64;
        self.nanos.fetch_add(n, Ordering::SeqCst);
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.set(10.0);
        assert!((c.now() - 10.0).abs() < 1e-9);
        c.set(5.0); // monotonic: no-op
        assert!((c.now() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        c.advance(0.01);
        let b = c.now();
        assert!(b >= a + 0.009);
    }
}
