//! Bench harness: a tiny criterion-analogue (the offline toolchain has no
//! criterion) providing warmup + timed iterations with percentile reporting,
//! plus the table printer every paper-figure bench uses.

use std::time::Instant;

use crate::util::hist::Samples;

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    s
}

/// Print a formatted table with a title (paper-style rows).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let widths: Vec<usize> = header
        .iter()
        .enumerate()
        .map(|(i, h)| {
            rows.iter()
                .map(|r| r.get(i).map(|c| c.len()).unwrap_or(0))
                .chain(std::iter::once(h.len()))
                .max()
                .unwrap_or(0)
        })
        .collect();
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&head));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for r in rows {
        println!("{}", fmt_row(r));
    }
}

/// Format seconds with adaptive units.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_collects_iters() {
        let s = time_it(2, 10, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(s.len(), 10);
        assert!(s.min() >= 0.0);
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(0.0000005).ends_with("µs"));
        assert!(fmt_secs(0.005).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }
}
