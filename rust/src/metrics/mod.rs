//! Experiment metrics: CSV series writers used by every bench to emit the
//! figure data alongside the printed tables.

use std::fs::{self, File};
use std::io::Write;
use std::path::Path;

/// A named CSV table accumulated in memory and flushed to `results/`.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(columns: &[&str]) -> CsvWriter {
        CsvWriter {
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format heterogeneous cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = File::create(path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Resident-set size of this process in bytes (Figure 8b memory tracking).
pub fn rss_bytes() -> u64 {
    if let Ok(status) = fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmRSS:") {
                let kb: u64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0);
                return kb * 1024;
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let mut w = CsvWriter::new(&["epoch", "hit_rate"]);
        w.rowf(&[&1, &0.25]);
        w.rowf(&[&2, &0.31]);
        let path = std::env::temp_dir().join("tvcache_test_metrics.csv");
        w.write(&path).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text, "epoch,hit_rate\n1,0.25\n2,0.31\n");
        fs::remove_file(&path).ok();
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".to_string()]);
    }

    #[test]
    fn rss_positive_on_linux() {
        assert!(rss_bytes() > 0);
    }
}
