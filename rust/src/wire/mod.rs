//! The TVCACHE binary wire codec: length-prefixed frames for the hot
//! endpoints (`/get`, `/put`, `/release`, and the `/cursor_*` family).
//!
//! The JSON text protocol serializes a lookup as the rollout's *entire*
//! tool history — O(L) bytes per call, O(L²) per rollout — and spends most
//! of its server time in the JSON parser. This codec frames the same
//! payloads as varint-prefixed byte strings, so a cursor step (the steady
//! state) is a few dozen bytes regardless of trajectory depth, and decoding
//! is a single forward scan with no allocation beyond the descriptor
//! strings themselves.
//!
//! Framing rules:
//!
//! * every **request** body begins with [`MAGIC`] (`0xB1`) — distinct from
//!   `{` (`0x7B`), so the shared endpoints (`/get`, `/put`, `/release`)
//!   sniff the first byte and keep accepting legacy JSON bodies;
//! * integers are LEB128 varints ([`put_varint`]);
//! * strings/bytes are varint length + raw bytes;
//! * `f64` is 8 bytes little-endian IEEE bits;
//! * a [`ToolCall`] is `tool, args, flags(u8: bit0 = mutates_state),
//!   key(u64 LE)` — the trailing key is the client's cached
//!   [`ToolCall::key`] fingerprint, which the server adopts via
//!   [`ToolCall::from_wire`] so child-index probes never re-hash;
//! * a [`ToolResult`] is `output, exec_time(f64), api_tokens(varint)`.
//!
//! Responses are binary only on binary requests (no magic byte — content
//! is negotiated by the request), and every binary response carries a
//! 12-byte trailer ([`seal_resp`], verified and stripped by
//! [`Reader::response`]): the server's **fencing epoch** (8 bytes LE, PR
//! 8) followed by an FNV-1a-32 checksum over payload + epoch. The
//! checksum turns a frame corrupted in flight into a decode failure that
//! degrades to a miss/fallback at the client instead of decoding to a
//! plausible-but-wrong value (varints have no redundancy of their own — a
//! bit-flipped node-id frame would otherwise decode cleanly to a
//! different node). The epoch rides *every* sealed frame — including the
//! `/capabilities` handshake — so a client that has seen a promotion can
//! reject answers from a revived stale primary ([`resp_epoch`]) without a
//! round trip of its own. The cold admin endpoints (`/stats`, `/persist`,
//! `/warm_start`, `/viz`, `/snapshot`) stay JSON: they run once per epoch
//! or per incident, human-debuggable output there is worth more than
//! bytes, and a JSON object truncated or corrupted in flight fails to
//! parse.
//!
//! The replication pull (`/replicate?from=`) is binary too: a
//! [`ReplicateBatch`] of tagged [`Op`] frames ([`enc_replicate_resp`] /
//! [`dec_replicate_resp`]), sealed like every other response so a garbled
//! batch can never replay into a follower.

use crate::cache::backend::{Capabilities, TurnBatch, TurnOp, TurnReply};
use crate::cache::key::{ToolCall, ToolResult};
use crate::cache::lpm::{CursorStep, Lookup, Miss};
use crate::cache::oplog::Op;
use crate::cache::tcg::{NodeId, SnapshotRef};

/// First byte of every binary request body (never `{`, so JSON sniffing
/// on the shared endpoints is unambiguous).
pub const MAGIC: u8 = 0xB1;

/// Response tags for lookup/step frames.
const TAG_MISS: u8 = 0;
const TAG_HIT: u8 = 1;
const TAG_INVALID: u8 = 2;

/// Does this request body use the binary codec?
pub fn is_binary(body: &[u8]) -> bool {
    body.first() == Some(&MAGIC)
}

/// FNV-1a over a frame body (32-bit: 4 bytes of trailer buys a ~2⁻³² false
/// accept on corrupted frames, which is beyond what the fault harness can
/// hit).
fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Size of the sealed-response trailer: 8-byte epoch + 4-byte checksum.
pub const RESP_TRAILER: usize = 12;

/// Seal a complete binary *response* frame: append the server's fencing
/// epoch (8 bytes LE) and the FNV-1a-32 of everything written so far
/// (payload + epoch, so a flipped epoch fails the checksum too). Every
/// top-level `enc_*_resp` ends with this; [`Reader::response`] is the
/// matching verifier and [`resp_epoch`] the fence-side extractor.
pub fn seal_resp(buf: &mut Vec<u8>, epoch: u64) {
    buf.extend_from_slice(&epoch.to_le_bytes());
    let sum = fnv1a32(buf);
    buf.extend_from_slice(&sum.to_le_bytes());
}

/// Verify a sealed response frame and extract its fencing epoch. Returns
/// `None` on truncation or checksum failure — exactly when
/// [`Reader::response`] would. Clients compare this against the highest
/// epoch they have seen and reject lower ones (split-brain guard).
pub fn resp_epoch(body: &[u8]) -> Option<u64> {
    if body.len() < RESP_TRAILER {
        return None;
    }
    let (sealed, trailer) = body.split_at(body.len() - 4);
    let want = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    if fnv1a32(sealed) != want {
        return None;
    }
    let epoch = &sealed[sealed.len() - 8..];
    Some(u64::from_le_bytes(epoch.try_into().ok()?))
}

// ---- primitive writers -------------------------------------------------

pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

pub fn put_call(buf: &mut Vec<u8>, c: &ToolCall) {
    put_str(buf, &c.tool);
    put_str(buf, &c.args);
    buf.push(c.mutates_state as u8);
    buf.extend_from_slice(&c.key().to_le_bytes());
}

pub fn put_result(buf: &mut Vec<u8>, r: &ToolResult) {
    put_str(buf, &r.output);
    put_f64(buf, r.exec_time);
    put_varint(buf, r.api_tokens);
}

// ---- reader ------------------------------------------------------------

/// A forward-only decoder over a frame. Every accessor returns `None` on
/// truncation or malformed input — callers map that to a 400 / a degraded
/// miss, never a panic.
pub struct Reader<'a> {
    b: &'a [u8],
}

impl<'a> Reader<'a> {
    /// Open a *request* frame: checks and consumes the [`MAGIC`] byte.
    pub fn request(body: &'a [u8]) -> Option<Reader<'a>> {
        match body.split_first() {
            Some((&MAGIC, rest)) => Some(Reader { b: rest }),
            _ => None,
        }
    }

    /// Open a bare frame body with no magic byte and no seal — for
    /// payloads whose integrity is guarded by an outer framing layer,
    /// like the CRC32-framed WAL records (`cache/wal.rs`).
    pub fn raw(body: &'a [u8]) -> Reader<'a> {
        Reader { b: body }
    }

    /// Open a *response* frame (no magic byte): verifies and strips the
    /// [`seal_resp`] trailer (epoch + checksum). A truncated or corrupted
    /// frame fails here, so response decoders only ever see intact bytes.
    /// The epoch is policy, not framing — callers that fence read it
    /// separately via [`resp_epoch`] before decoding.
    pub fn response(body: &'a [u8]) -> Option<Reader<'a>> {
        if body.len() < RESP_TRAILER {
            return None;
        }
        let (sealed, trailer) = body.split_at(body.len() - 4);
        let want = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
        (fnv1a32(sealed) == want).then_some(Reader { b: &sealed[..sealed.len() - 8] })
    }

    pub fn u8(&mut self) -> Option<u8> {
        let (&v, rest) = self.b.split_first()?;
        self.b = rest;
        Some(v)
    }

    pub fn varint(&mut self) -> Option<u64> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let byte = self.u8()?;
            if shift >= 64 {
                return None; // over-long encoding
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Some(v);
            }
            shift += 7;
        }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.b.len() < n {
            return None;
        }
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Some(head)
    }

    /// Raw bytes of a known length (callers read the varint length first).
    pub fn take_bytes(&mut self, n: usize) -> Option<&'a [u8]> {
        self.take(n)
    }

    pub fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64_le()?))
    }

    pub fn u64_le(&mut self) -> Option<u64> {
        let head = self.take(8)?;
        Some(u64::from_le_bytes(head.try_into().ok()?))
    }

    pub fn str(&mut self) -> Option<&'a str> {
        let len = self.varint()?;
        if len > usize::MAX as u64 {
            return None;
        }
        let head = self.take(len as usize)?;
        std::str::from_utf8(head).ok()
    }

    pub fn call(&mut self) -> Option<ToolCall> {
        let tool = self.str()?;
        let args = self.str()?;
        let flags = self.u8()?;
        let key = self.u64_le()?;
        Some(ToolCall::from_wire(tool, args, flags & 1 != 0, key))
    }

    pub fn result(&mut self) -> Option<ToolResult> {
        let output = self.str()?.to_string();
        let exec_time = self.f64()?;
        let api_tokens = self.varint()?;
        Some(ToolResult { output, exec_time, api_tokens })
    }

    /// True when the frame is fully consumed (strict decoders check this).
    pub fn done(&self) -> bool {
        self.b.is_empty()
    }
}

// ---- request frames ----------------------------------------------------

/// `/get` — full-prefix lookup: `task, n, n × call`.
pub fn enc_lookup(buf: &mut Vec<u8>, task: &str, q: &[ToolCall]) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, q.len() as u64);
    for c in q {
        put_call(buf, c);
    }
}

/// `/put` — full-trajectory insert: `task, n, n × (call, result)`.
pub fn enc_insert(buf: &mut Vec<u8>, task: &str, traj: &[(ToolCall, ToolResult)]) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, traj.len() as u64);
    for (c, r) in traj {
        put_call(buf, c);
        put_result(buf, r);
    }
}

/// `/release` — `task, node`.
pub fn enc_release(buf: &mut Vec<u8>, task: &str, node: usize) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, node as u64);
}

/// `/cursor_open` — `task`.
pub fn enc_cursor_open(buf: &mut Vec<u8>, task: &str) {
    buf.push(MAGIC);
    put_str(buf, task);
}

/// `/cursor_step` — the O(1) hot frame: `task, cursor, call`.
pub fn enc_cursor_step(buf: &mut Vec<u8>, task: &str, cursor: u64, call: &ToolCall) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, cursor);
    put_call(buf, call);
}

/// `/cursor_record` — `task, cursor, call, result`.
pub fn enc_cursor_record(
    buf: &mut Vec<u8>,
    task: &str,
    cursor: u64,
    call: &ToolCall,
    result: &ToolResult,
) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, cursor);
    put_call(buf, call);
    put_result(buf, result);
}

/// `/cursor_seek` — `task, cursor, node, steps`.
pub fn enc_cursor_seek(buf: &mut Vec<u8>, task: &str, cursor: u64, node: usize, steps: usize) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, cursor);
    put_varint(buf, node as u64);
    put_varint(buf, steps as u64);
}

/// `/cursor_close` — `task, cursor`.
pub fn enc_cursor_close(buf: &mut Vec<u8>, task: &str, cursor: u64) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, cursor);
}

// ---- session API v2 frames ---------------------------------------------

/// [`TurnOp`] tags in a turn frame.
const OP_NONE: u8 = 0;
const OP_STEP: u8 = 1;
const OP_RECORD: u8 = 2;

/// `/capabilities` — the client hello: just the protocol generation.
pub fn enc_hello(buf: &mut Vec<u8>, proto: u64) {
    buf.push(MAGIC);
    put_varint(buf, proto);
}

/// Server side of the hello. Returns the client's protocol generation.
pub fn dec_hello(body: &[u8]) -> Option<u64> {
    let mut r = Reader::request(body)?;
    let proto = r.varint()?;
    r.done().then_some(proto)
}

/// `/capabilities` response: `proto, flags(u8: bit0 binary, bit1 cursors,
/// bit2 turn_batch, bit3 payload_dedup)`. New capabilities claim further
/// bits of the *same* flags byte, so the PR 4 frame layout is unchanged —
/// old clients mask the bits they know, old servers leave bit3 clear.
pub fn enc_caps_resp(buf: &mut Vec<u8>, proto: u64, caps: &Capabilities, epoch: u64) {
    put_varint(buf, proto);
    let flags = (caps.binary as u8)
        | ((caps.cursors as u8) << 1)
        | ((caps.turn_batch as u8) << 2)
        | ((caps.payload_dedup as u8) << 3);
    buf.push(flags);
    seal_resp(buf, epoch);
}

pub fn dec_caps_resp(body: &[u8]) -> Option<(u64, Capabilities)> {
    let mut r = Reader::response(body)?;
    let proto = r.varint()?;
    let flags = r.u8()?;
    let caps = Capabilities {
        binary: flags & 1 != 0,
        cursors: flags & 2 != 0,
        turn_batch: flags & 4 != 0,
        payload_dedup: flags & 8 != 0,
    };
    r.done().then_some((proto, caps))
}

/// Extended `/capabilities` hello (PR 10): `proto, expect_node`. The
/// trailing string names the node the client's cluster ring *expects* to
/// be talking to, so a misrouted connection is rejected at the handshake
/// instead of silently caching on the wrong group. A separate frame — not
/// a tolerant [`dec_hello`] — because the plain decoders are strict on
/// trailing bytes by design (a truncation/garble must never half-decode),
/// and the server only replies with the extended caps frame when the
/// client sent the extended hello, so legacy peers never see it.
pub fn enc_hello_ext(buf: &mut Vec<u8>, proto: u64, expect_node: &str) {
    buf.push(MAGIC);
    put_varint(buf, proto);
    put_str(buf, expect_node);
}

/// Server side of the hello, accepting both forms. Returns the protocol
/// generation and, for the extended frame, the node id the client expects.
pub fn dec_hello_any(body: &[u8]) -> Option<(u64, Option<&str>)> {
    let mut r = Reader::request(body)?;
    let proto = r.varint()?;
    if r.done() {
        return Some((proto, None));
    }
    let expect = r.str()?;
    r.done().then_some((proto, Some(expect)))
}

/// Extended `/capabilities` response: `proto, flags, node_id` (sealed).
/// Sent only in answer to [`enc_hello_ext`]; the plain frame stays the
/// wire default so pre-cluster clients keep strict decoding.
pub fn enc_caps_resp_ext(
    buf: &mut Vec<u8>,
    proto: u64,
    caps: &Capabilities,
    node_id: &str,
    epoch: u64,
) {
    put_varint(buf, proto);
    let flags = (caps.binary as u8)
        | ((caps.cursors as u8) << 1)
        | ((caps.turn_batch as u8) << 2)
        | ((caps.payload_dedup as u8) << 3);
    buf.push(flags);
    put_str(buf, node_id);
    seal_resp(buf, epoch);
}

/// Client side of the caps response, accepting both forms. `node_id` is
/// `None` when the server answered with the plain (pre-cluster) frame.
pub fn dec_caps_resp_ext(body: &[u8]) -> Option<(u64, Capabilities, Option<String>)> {
    let mut r = Reader::response(body)?;
    let proto = r.varint()?;
    let flags = r.u8()?;
    let caps = Capabilities {
        binary: flags & 1 != 0,
        cursors: flags & 2 != 0,
        turn_batch: flags & 4 != 0,
        payload_dedup: flags & 8 != 0,
    };
    if r.done() {
        return Some((proto, caps, None));
    }
    let node = r.str()?.to_string();
    r.done().then_some((proto, caps, Some(node)))
}

/// `/session_turn` — one reasoning turn's batched ops: `task, cursor
/// (0 = open a session first), n_probes, n × call, op_tag, [call,
/// [result]]`. The steady-state turn frame replaces N per-call round
/// trips with one.
pub fn enc_turn(buf: &mut Vec<u8>, task: &str, cursor: u64, batch: &TurnBatch) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, cursor);
    put_varint(buf, batch.probes.len() as u64);
    for p in &batch.probes {
        put_call(buf, p);
    }
    match &batch.op {
        TurnOp::None => buf.push(OP_NONE),
        TurnOp::Step(call) => {
            buf.push(OP_STEP);
            put_call(buf, call);
        }
        TurnOp::Record(call, result) => {
            buf.push(OP_RECORD);
            put_call(buf, call);
            put_result(buf, result);
        }
    }
}

/// Server side of the turn frame. Probe counts are capped like every other
/// repeated field (a malicious length never pre-allocates unbounded).
pub fn dec_turn_req(body: &[u8]) -> Option<(String, u64, TurnBatch)> {
    let mut r = Reader::request(body)?;
    let task = r.str()?.to_string();
    let cursor = r.varint()?;
    let n = r.varint()? as usize;
    let mut probes = Vec::with_capacity(n.min(64));
    for _ in 0..n {
        probes.push(r.call()?);
    }
    let op = match r.u8()? {
        OP_NONE => TurnOp::None,
        OP_STEP => TurnOp::Step(r.call()?),
        OP_RECORD => {
            let call = r.call()?;
            let result = r.result()?;
            TurnOp::Record(call, result)
        }
        _ => return None,
    };
    r.done().then_some((task, cursor, TurnBatch { probes, op }))
}

/// Turn response: `cursor (0 = refused), n_probes, n × (0 | 1 + result),
/// op_tag, [step_resp | node]`. Self-describing, so the decoder needs no
/// request context.
pub fn enc_turn_resp(buf: &mut Vec<u8>, reply: &TurnReply, epoch: u64) {
    put_varint(buf, reply.cursor);
    put_varint(buf, reply.probes.len() as u64);
    for p in &reply.probes {
        match p {
            Some(result) => {
                buf.push(1);
                put_result(buf, result);
            }
            None => buf.push(0),
        }
    }
    match (&reply.step, &reply.recorded) {
        (Some(step), _) => {
            buf.push(OP_STEP);
            put_step(buf, step);
        }
        (None, Some(node)) => {
            buf.push(OP_RECORD);
            put_varint(buf, *node as u64);
        }
        (None, None) => buf.push(OP_NONE),
    }
    seal_resp(buf, epoch);
}

pub fn dec_turn_resp(body: &[u8]) -> Option<TurnReply> {
    let mut r = Reader::response(body)?;
    let cursor = r.varint()?;
    let n = r.varint()? as usize;
    let mut probes = Vec::with_capacity(n.min(64));
    for _ in 0..n {
        probes.push(match r.u8()? {
            0 => None,
            1 => Some(r.result()?),
            _ => return None,
        });
    }
    let (step, recorded) = match r.u8()? {
        OP_NONE => (None, None),
        OP_STEP => (Some(read_step(&mut r)?), None),
        OP_RECORD => (None, Some(r.varint()? as NodeId)),
        _ => return None,
    };
    r.done().then_some(TurnReply { cursor, probes, step, recorded })
}

/// `/session_release` — return a session-owned resume pin: `task, cursor,
/// node`.
pub fn enc_session_release(buf: &mut Vec<u8>, task: &str, cursor: u64, node: NodeId) {
    buf.push(MAGIC);
    put_str(buf, task);
    put_varint(buf, cursor);
    put_varint(buf, node as u64);
}

// ---- response frames ---------------------------------------------------

fn put_miss(buf: &mut Vec<u8>, m: &Miss) {
    buf.push(TAG_MISS);
    put_varint(buf, m.matched_node as u64);
    put_varint(buf, m.matched_calls as u64);
    match m.resume {
        Some((node, snap, replay_from)) => {
            buf.push(1);
            put_varint(buf, node as u64);
            put_varint(buf, snap.id);
            put_f64(buf, snap.restore_cost);
            put_varint(buf, replay_from as u64);
        }
        None => buf.push(0),
    }
}

fn read_miss(r: &mut Reader) -> Option<Miss> {
    let matched_node = r.varint()? as usize;
    let matched_calls = r.varint()? as usize;
    let resume = match r.u8()? {
        0 => None,
        _ => {
            let node = r.varint()? as usize;
            let id = r.varint()?;
            let restore_cost = r.f64()?;
            let replay_from = r.varint()? as usize;
            // The wire carries no payload size (the client never needs it
            // before fetching) — parity with the JSON protocol's `bytes: 0`.
            Some((node, SnapshotRef { id, bytes: 0, restore_cost }, replay_from))
        }
    };
    Some(Miss { matched_node, matched_calls, resume })
}

/// Lookup response: `tag, …` (`1` hit: `node, result`; `0` miss).
pub fn enc_lookup_resp(buf: &mut Vec<u8>, out: &Lookup, epoch: u64) {
    match out {
        Lookup::Hit { node, result } => {
            buf.push(TAG_HIT);
            put_varint(buf, *node as u64);
            put_result(buf, result);
        }
        Lookup::Miss(m) => put_miss(buf, m),
    }
    seal_resp(buf, epoch);
}

pub fn dec_lookup_resp(body: &[u8]) -> Option<Lookup> {
    let mut r = Reader::response(body)?;
    let out = match r.u8()? {
        TAG_HIT => Lookup::Hit { node: r.varint()? as usize, result: r.result()? },
        TAG_MISS => Lookup::Miss(read_miss(&mut r)?),
        _ => return None,
    };
    r.done().then_some(out)
}

/// Write one step-outcome frame body (unsealed: shared by `/cursor_step`
/// responses and the step slot of a turn response).
fn put_step(buf: &mut Vec<u8>, out: &CursorStep) {
    match out {
        CursorStep::Hit { node, result } => {
            buf.push(TAG_HIT);
            put_varint(buf, *node as u64);
            put_result(buf, result);
        }
        CursorStep::Miss(m) => put_miss(buf, m),
        CursorStep::Invalid => buf.push(TAG_INVALID),
    }
}

/// Cursor-step response: a lookup frame plus the `2` (invalid) tag.
pub fn enc_step_resp(buf: &mut Vec<u8>, out: &CursorStep, epoch: u64) {
    put_step(buf, out);
    seal_resp(buf, epoch);
}

/// Read one step-outcome frame body (shared by `/cursor_step` responses
/// and the step slot of a turn response).
fn read_step(r: &mut Reader) -> Option<CursorStep> {
    Some(match r.u8()? {
        TAG_HIT => CursorStep::Hit { node: r.varint()? as usize, result: r.result()? },
        TAG_MISS => CursorStep::Miss(read_miss(r)?),
        TAG_INVALID => CursorStep::Invalid,
        _ => return None,
    })
}

pub fn dec_step_resp(body: &[u8]) -> Option<CursorStep> {
    let mut r = Reader::response(body)?;
    let out = read_step(&mut r)?;
    r.done().then_some(out)
}

/// Node-id response (`/put`, `/cursor_record`, `/cursor_open`'s cursor id).
pub fn enc_u64_resp(buf: &mut Vec<u8>, v: u64, epoch: u64) {
    put_varint(buf, v);
    seal_resp(buf, epoch);
}

pub fn dec_u64_resp(body: &[u8]) -> Option<u64> {
    let mut r = Reader::response(body)?;
    let v = r.varint()?;
    r.done().then_some(v)
}

/// Boolean response (`/cursor_seek`).
pub fn enc_bool_resp(buf: &mut Vec<u8>, ok: bool, epoch: u64) {
    buf.push(ok as u8);
    seal_resp(buf, epoch);
}

pub fn dec_bool_resp(body: &[u8]) -> Option<bool> {
    let mut r = Reader::response(body)?;
    let v = r.u8()?;
    r.done().then_some(v != 0)
}

// ---- replication frames (PR 8) -----------------------------------------

/// [`Op`] tags in a `/replicate` batch.
const OPR_INSERT: u8 = 1;
const OPR_RECORD: u8 = 2;
const OPR_ATTACH: u8 = 3;
const OPR_RELEASE: u8 = 4;
const OPR_WARM_FORK: u8 = 5;
const OPR_EVICT_SNAPSHOT: u8 = 6;
const OPR_EVICT_NODE: u8 = 7;

/// One `/replicate?from=` pull's worth of op-log, decoded. The epoch is
/// lifted out of the sealed trailer so the follower can fence its primary.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicateBatch {
    /// Sequence number of `ops[0]` — above the requested `from` exactly
    /// when the primary's window no longer reaches back that far (the
    /// follower must freeze rather than replay across the gap).
    pub start: u64,
    /// The primary's next sequence number (lag = `next − applied`).
    pub next: u64,
    /// The primary's shard count: replay is only faithful on a follower
    /// with an identical shard topology (same router, same id strides).
    pub shards: u64,
    /// The primary's fencing epoch (from the sealed trailer).
    pub epoch: u64,
    pub ops: Vec<Op>,
}

/// Encode one [`Op`] (tag + body). Public because it is the durable
/// record codec too: the WAL (`cache/wal.rs`) frames exactly these bytes
/// under its own length + CRC32 header, so the on-disk log and the
/// `/replicate` wire can never drift apart.
pub fn put_op(buf: &mut Vec<u8>, op: &Op) {
    match op {
        Op::Insert { task, traj } => {
            buf.push(OPR_INSERT);
            put_str(buf, task);
            put_varint(buf, traj.len() as u64);
            for (c, r) in traj {
                put_call(buf, c);
                put_result(buf, r);
            }
        }
        Op::Record { task, node, call, result } => {
            buf.push(OPR_RECORD);
            put_str(buf, task);
            put_varint(buf, *node as u64);
            put_call(buf, call);
            put_result(buf, result);
        }
        Op::Attach { task, node, id, key, bytes, byte_len, serialize_cost, restore_cost } => {
            buf.push(OPR_ATTACH);
            put_str(buf, task);
            put_varint(buf, *node as u64);
            put_varint(buf, *id);
            for lane in key.0 {
                buf.extend_from_slice(&lane.to_le_bytes());
            }
            match bytes {
                Some(b) => {
                    buf.push(1);
                    put_varint(buf, b.len() as u64);
                    buf.extend_from_slice(b);
                }
                None => buf.push(0),
            }
            put_varint(buf, *byte_len);
            put_f64(buf, *serialize_cost);
            put_f64(buf, *restore_cost);
        }
        Op::Release { task, node } => {
            buf.push(OPR_RELEASE);
            put_str(buf, task);
            put_varint(buf, *node as u64);
        }
        Op::WarmFork { task, node, warm } => {
            buf.push(OPR_WARM_FORK);
            put_str(buf, task);
            put_varint(buf, *node as u64);
            buf.push(*warm as u8);
        }
        Op::EvictSnapshot { task, node } => {
            buf.push(OPR_EVICT_SNAPSHOT);
            put_str(buf, task);
            put_varint(buf, *node as u64);
        }
        Op::EvictNode { task, node } => {
            buf.push(OPR_EVICT_NODE);
            put_str(buf, task);
            put_varint(buf, *node as u64);
        }
    }
}

/// Decode one [`Op`] — the inverse of [`put_op`], shared by
/// [`dec_replicate_resp`] and WAL segment recovery. `None` on any
/// truncation, malformed field, or unknown tag.
pub fn read_op(r: &mut Reader) -> Option<Op> {
    let tag = r.u8()?;
    let task = r.str()?.to_string();
    Some(match tag {
        OPR_INSERT => {
            let n = r.varint()? as usize;
            let mut traj = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                let call = r.call()?;
                let result = r.result()?;
                traj.push((call, result));
            }
            Op::Insert { task, traj }
        }
        OPR_RECORD => {
            let node = r.varint()? as NodeId;
            let call = r.call()?;
            let result = r.result()?;
            Op::Record { task, node, call, result }
        }
        OPR_ATTACH => {
            let node = r.varint()? as NodeId;
            let id = r.varint()?;
            let key = crate::cache::payload::ContentKey([
                r.u64_le()?,
                r.u64_le()?,
                r.u64_le()?,
                r.u64_le()?,
            ]);
            let bytes = match r.u8()? {
                0 => None,
                1 => {
                    let len = r.varint()?;
                    if len > usize::MAX as u64 {
                        return None;
                    }
                    Some(r.take_bytes(len as usize)?.into())
                }
                _ => return None,
            };
            let byte_len = r.varint()?;
            let serialize_cost = r.f64()?;
            let restore_cost = r.f64()?;
            Op::Attach { task, node, id, key, bytes, byte_len, serialize_cost, restore_cost }
        }
        OPR_RELEASE => Op::Release { task, node: r.varint()? as NodeId },
        OPR_WARM_FORK => {
            let node = r.varint()? as NodeId;
            let warm = r.u8()? != 0;
            Op::WarmFork { task, node, warm }
        }
        OPR_EVICT_SNAPSHOT => Op::EvictSnapshot { task, node: r.varint()? as NodeId },
        OPR_EVICT_NODE => Op::EvictNode { task, node: r.varint()? as NodeId },
        _ => return None,
    })
}

/// `/replicate` response: `start, next, shards, n, n × op`, sealed with
/// the primary's epoch like every binary response.
pub fn enc_replicate_resp(
    buf: &mut Vec<u8>,
    start: u64,
    next: u64,
    shards: u64,
    ops: &[Op],
    epoch: u64,
) {
    put_varint(buf, start);
    put_varint(buf, next);
    put_varint(buf, shards);
    put_varint(buf, ops.len() as u64);
    for op in ops {
        put_op(buf, op);
    }
    seal_resp(buf, epoch);
}

/// Follower side of the pull. `None` on truncation, corruption, or any
/// unknown op tag — a batch that fails to decode is skipped whole (the
/// follower re-pulls), never half-applied.
pub fn dec_replicate_resp(body: &[u8]) -> Option<ReplicateBatch> {
    let epoch = resp_epoch(body)?;
    let mut r = Reader::response(body)?;
    let start = r.varint()?;
    let next = r.varint()?;
    let shards = r.varint()?;
    let n = r.varint()? as usize;
    let mut ops = Vec::with_capacity(n.min(512));
    for _ in 0..n {
        ops.push(read_op(&mut r)?);
    }
    r.done().then_some(ReplicateBatch { start, next, shards, epoch, ops })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calls() -> Vec<ToolCall> {
        vec![
            ToolCall::new("bash", "make && ./run \"x\""),
            ToolCall::stateless("caption_retrieval", "(0, 10)"),
            ToolCall::new("sql", "SELECT * FROM t WHERE a = 'ünïcødé 😀';"),
        ]
    }

    #[test]
    fn varint_roundtrip_boundaries() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            seal_resp(&mut buf, 7);
            let mut r = Reader::response(&buf).unwrap();
            assert_eq!(r.varint(), Some(v));
            assert!(r.done());
        }
    }

    #[test]
    fn lookup_request_roundtrip_preserves_calls_and_keys() {
        let q = calls();
        let mut buf = Vec::new();
        enc_lookup(&mut buf, "task-7", &q);
        assert!(is_binary(&buf));
        let mut r = Reader::request(&buf).unwrap();
        assert_eq!(r.str(), Some("task-7"));
        let n = r.varint().unwrap() as usize;
        assert_eq!(n, q.len());
        for want in &q {
            let got = r.call().unwrap();
            assert_eq!(&got, want);
            assert_eq!(got.key(), want.key(), "wire must carry the cached fingerprint");
        }
        assert!(r.done());
    }

    #[test]
    fn insert_request_roundtrip() {
        let traj: Vec<(ToolCall, ToolResult)> = calls()
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                let r = ToolResult {
                    output: format!("out-{i}\nline"),
                    exec_time: 0.5 * i as f64,
                    api_tokens: i as u64,
                };
                (c, r)
            })
            .collect();
        let mut buf = Vec::new();
        enc_insert(&mut buf, "t", &traj);
        let mut r = Reader::request(&buf).unwrap();
        assert_eq!(r.str(), Some("t"));
        let n = r.varint().unwrap() as usize;
        let got: Vec<(ToolCall, ToolResult)> =
            (0..n).map(|_| (r.call().unwrap(), r.result().unwrap())).collect();
        assert_eq!(got, traj);
        assert!(r.done());
    }

    #[test]
    fn lookup_response_roundtrip_hit_and_miss() {
        let hit = Lookup::Hit {
            node: 42,
            result: ToolResult { output: "12 passed".into(), exec_time: 3.25, api_tokens: 9 },
        };
        let miss_with_resume = Lookup::Miss(Miss {
            matched_node: 7,
            matched_calls: 3,
            resume: Some((7, SnapshotRef { id: 99, bytes: 0, restore_cost: 0.75 }, 2)),
        });
        let plain_miss =
            Lookup::Miss(Miss { matched_node: 0, matched_calls: 0, resume: None });
        for want in [hit, miss_with_resume, plain_miss] {
            let mut buf = Vec::new();
            enc_lookup_resp(&mut buf, &want, 7);
            assert_eq!(dec_lookup_resp(&buf), Some(want));
        }
    }

    #[test]
    fn step_response_roundtrip_including_invalid() {
        for want in [
            CursorStep::Hit { node: 5, result: ToolResult::new("r", 1.0) },
            CursorStep::Miss(Miss { matched_node: 5, matched_calls: 4, resume: None }),
            CursorStep::Invalid,
        ] {
            let mut buf = Vec::new();
            enc_step_resp(&mut buf, &want, 7);
            assert_eq!(dec_step_resp(&buf), Some(want));
        }
    }

    #[test]
    fn cursor_frames_are_depth_independent() {
        // The whole point: a step frame's size depends only on the delta
        // call, never on trajectory depth.
        let call = ToolCall::new("bash", "make test");
        let mut shallow = Vec::new();
        enc_cursor_step(&mut shallow, "t", 1, &call);
        let mut deep = Vec::new();
        enc_cursor_step(&mut deep, "t", u64::MAX, &call);
        assert!(deep.len() <= shallow.len() + 9, "cursor id is the only variable part");
    }

    #[test]
    fn truncated_and_malformed_frames_never_panic() {
        let mut buf = Vec::new();
        enc_insert(&mut buf, "task", &[(ToolCall::new("a", "b"), ToolResult::new("r", 1.0))]);
        for cut in 0..buf.len() {
            let mut r = match Reader::request(&buf[..cut]) {
                Some(r) => r,
                None => continue,
            };
            // Decoding a truncated frame returns None somewhere, never panics.
            let _ = r
                .str()
                .and_then(|_| r.varint())
                .and_then(|_| r.call())
                .and_then(|_| r.result());
        }
        assert_eq!(dec_lookup_resp(&[]), None);
        assert_eq!(dec_lookup_resp(&[9, 9, 9]), None);
        assert_eq!(dec_step_resp(&[TAG_HIT]), None);
        assert_eq!(dec_u64_resp(&[0x80]), None);
        // Trailing garbage is rejected by strict decoders.
        let mut buf = Vec::new();
        enc_bool_resp(&mut buf, true, 7);
        buf.push(0);
        assert_eq!(dec_bool_resp(&buf), None);
    }

    #[test]
    fn garbled_sealed_responses_never_decode() {
        // A bare varint frame would absorb the fault harness's bit flips
        // and decode to a *different valid node id*; the seal must turn
        // every such corruption into a decode failure.
        for v in [0u64, 1, 5, 127, 128, 300, 99_999] {
            let mut buf = Vec::new();
            enc_u64_resp(&mut buf, v, 7);
            crate::util::fault::garble(&mut buf);
            assert_eq!(dec_u64_resp(&buf), None, "node id {v}");
        }
        for ok in [false, true] {
            let mut buf = Vec::new();
            enc_bool_resp(&mut buf, ok, 7);
            crate::util::fault::garble(&mut buf);
            assert_eq!(dec_bool_resp(&buf), None, "bool {ok}");
        }
        let hit = Lookup::Hit { node: 7, result: ToolResult::new("12 passed", 1.0) };
        let mut buf = Vec::new();
        enc_lookup_resp(&mut buf, &hit, 7);
        crate::util::fault::garble(&mut buf);
        assert_eq!(dec_lookup_resp(&buf), None, "garbled hit must not decode");
    }

    fn turn_batches() -> Vec<TurnBatch> {
        let probes = vec![
            ToolCall::stateless("bash", "cat cfg.txt"),
            ToolCall::stateless("bash", "ls -la"),
        ];
        vec![
            TurnBatch { probes: probes.clone(), op: TurnOp::None },
            TurnBatch { probes: probes.clone(), op: TurnOp::Step(ToolCall::new("bash", "make")) },
            TurnBatch {
                probes: Vec::new(),
                op: TurnOp::Record(
                    ToolCall::new("bash", "make test"),
                    ToolResult { output: "12 passed".into(), exec_time: 3.5, api_tokens: 7 },
                ),
            },
        ]
    }

    #[test]
    fn turn_request_roundtrip_all_ops() {
        for want in turn_batches() {
            let mut buf = Vec::new();
            enc_turn(&mut buf, "turn-task", 42, &want);
            assert!(is_binary(&buf));
            let (task, cursor, got) = dec_turn_req(&buf).unwrap();
            assert_eq!(task, "turn-task");
            assert_eq!(cursor, 42);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn turn_response_roundtrip_all_shapes() {
        let replies = vec![
            TurnReply { cursor: 0, probes: vec![None, None], step: None, recorded: Some(0) },
            TurnReply {
                cursor: 9,
                probes: vec![Some(ToolResult::new("alpha", 0.5)), None],
                step: Some(CursorStep::Hit { node: 3, result: ToolResult::new("r", 1.0) }),
                recorded: None,
            },
            TurnReply {
                cursor: 9,
                probes: Vec::new(),
                step: Some(CursorStep::Miss(Miss {
                    matched_node: 4,
                    matched_calls: 2,
                    resume: Some((4, SnapshotRef { id: 8, bytes: 0, restore_cost: 0.3 }, 2)),
                })),
                recorded: None,
            },
            TurnReply { cursor: 9, probes: vec![None], step: None, recorded: Some(17) },
            TurnReply {
                cursor: 9,
                probes: Vec::new(),
                step: Some(CursorStep::Invalid),
                recorded: None,
            },
        ];
        for want in replies {
            let mut buf = Vec::new();
            enc_turn_resp(&mut buf, &want, 7);
            assert_eq!(dec_turn_resp(&buf), Some(want));
        }
    }

    #[test]
    fn capability_frames_roundtrip() {
        let mut buf = Vec::new();
        enc_hello(&mut buf, Capabilities::PROTO_V2);
        assert!(is_binary(&buf));
        assert_eq!(dec_hello(&buf), Some(Capabilities::PROTO_V2));

        for caps in [Capabilities::V2, Capabilities::LEGACY, Capabilities::CORE] {
            let mut buf = Vec::new();
            enc_caps_resp(&mut buf, Capabilities::PROTO_V2, &caps, 7);
            assert_eq!(dec_caps_resp(&buf), Some((Capabilities::PROTO_V2, caps)));
        }
    }

    #[test]
    fn extended_capability_flags_roundtrip_exhaustively() {
        // The payload_dedup bit extended the flags byte in place (bit3):
        // every combination of the four known bits must survive the wire
        // unchanged, and the strict decoder must still reject trailers.
        for flags in 0u8..16 {
            let caps = Capabilities {
                binary: flags & 1 != 0,
                cursors: flags & 2 != 0,
                turn_batch: flags & 4 != 0,
                payload_dedup: flags & 8 != 0,
            };
            let mut buf = Vec::new();
            enc_caps_resp(&mut buf, Capabilities::PROTO_V2, &caps, 7);
            assert_eq!(dec_caps_resp(&buf), Some((Capabilities::PROTO_V2, caps)));
            buf.push(0xAB);
            assert_eq!(dec_caps_resp(&buf), None, "trailing byte at flags {flags}");
        }
        // A future server may claim bits this client does not know: the
        // unknown high bits are masked off, never a parse failure.
        let mut raw = vec![2u8, 0xFF];
        seal_resp(&mut raw, 1);
        assert_eq!(
            dec_caps_resp(&raw),
            Some((2, Capabilities::V2)),
            "unknown capability bits must be ignored"
        );
    }

    #[test]
    fn node_identity_hello_frames_roundtrip_and_interop() {
        // Extended hello round-trips through the tolerant decoder...
        let mut buf = Vec::new();
        enc_hello_ext(&mut buf, Capabilities::PROTO_V2, "g1/primary");
        assert!(is_binary(&buf));
        assert_eq!(dec_hello_any(&buf), Some((Capabilities::PROTO_V2, Some("g1/primary"))));
        // ...and the strict plain decoder rejects it (old servers must not
        // half-decode a frame they do not understand).
        assert_eq!(dec_hello(&buf), None);
        // The plain hello decodes through both.
        let mut plain = Vec::new();
        enc_hello(&mut plain, Capabilities::PROTO_V2);
        assert_eq!(dec_hello_any(&plain), Some((Capabilities::PROTO_V2, None)));
        assert_eq!(dec_hello(&plain), Some(Capabilities::PROTO_V2));
        // An empty expectation is a valid frame, distinct from no frame.
        let mut empty = Vec::new();
        enc_hello_ext(&mut empty, Capabilities::PROTO_V2, "");
        assert_eq!(dec_hello_any(&empty), Some((Capabilities::PROTO_V2, Some(""))));

        // Extended caps response round-trips with the node id...
        for caps in [Capabilities::V2, Capabilities::LEGACY, Capabilities::CORE] {
            let mut resp = Vec::new();
            enc_caps_resp_ext(&mut resp, Capabilities::PROTO_V2, &caps, "g1/primary", 7);
            assert_eq!(
                dec_caps_resp_ext(&resp),
                Some((Capabilities::PROTO_V2, caps, Some("g1/primary".to_string())))
            );
            // ...the strict plain decoder rejects the trailing id...
            assert_eq!(dec_caps_resp(&resp), None);
            // ...and the tolerant decoder accepts a plain response.
            let mut plain = Vec::new();
            enc_caps_resp(&mut plain, Capabilities::PROTO_V2, &caps, 7);
            assert_eq!(dec_caps_resp_ext(&plain), Some((Capabilities::PROTO_V2, caps, None)));
        }
    }

    #[test]
    fn node_identity_hello_frames_survive_truncation_and_garble_fuzz() {
        // Every strict prefix of the extended hello either fails to decode
        // or (the flags-only prefix) decodes as a plain hello — it must
        // never panic or half-read the node id.
        let mut hello = Vec::new();
        enc_hello_ext(&mut hello, Capabilities::PROTO_V2, "group-a/follower");
        for cut in 0..hello.len() {
            let got = dec_hello_any(&hello[..cut]);
            assert!(
                got.is_none() || got == Some((Capabilities::PROTO_V2, None)),
                "truncated ext hello at {cut}: {got:?}"
            );
        }
        // The sealed extended caps frame rejects every truncation outright
        // (the seal covers the id bytes) and never survives a garble.
        let mut resp = Vec::new();
        enc_caps_resp_ext(&mut resp, Capabilities::PROTO_V2, &Capabilities::V2, "g0/primary", 7);
        for cut in 0..resp.len() {
            assert_eq!(dec_caps_resp_ext(&resp[..cut]), None, "truncated ext caps at {cut}");
        }
        let mut garbled = resp.clone();
        crate::util::fault::garble(&mut garbled);
        assert_eq!(dec_caps_resp_ext(&garbled), None, "garbled ext caps must not decode");
        for i in 0..resp.len() {
            let mut flipped = resp.clone();
            flipped[i] ^= 0xA5;
            assert_eq!(dec_caps_resp_ext(&flipped), None, "flipped byte {i} must not decode");
        }
    }

    #[test]
    fn turn_and_capability_frames_survive_truncation_fuzz() {
        // Every prefix of every frame decodes to None (or a shorter valid
        // frame — impossible here because strict decoders require full
        // consumption), and never panics.
        let mut frames: Vec<Vec<u8>> = Vec::new();
        for b in turn_batches() {
            let mut buf = Vec::new();
            enc_turn(&mut buf, "t", 7, &b);
            frames.push(buf);
        }
        let mut buf = Vec::new();
        enc_hello(&mut buf, Capabilities::PROTO_V2);
        frames.push(buf);
        let mut buf = Vec::new();
        enc_session_release(&mut buf, "t", 7, 3);
        frames.push(buf);
        for frame in &frames {
            for cut in 0..frame.len() {
                assert_eq!(dec_turn_req(&frame[..cut]), None, "truncated req at {cut}");
            }
        }
        let mut resp = Vec::new();
        enc_turn_resp(
            &mut resp,
            &TurnReply {
                cursor: 5,
                probes: vec![Some(ToolResult::new("x", 1.0)), None],
                step: Some(CursorStep::Miss(Miss {
                    matched_node: 1,
                    matched_calls: 1,
                    resume: None,
                })),
                recorded: None,
            },
            7,
        );
        for cut in 0..resp.len() {
            assert_eq!(dec_turn_resp(&resp[..cut]), None, "truncated resp at {cut}");
        }
        let mut caps = Vec::new();
        enc_caps_resp(&mut caps, Capabilities::PROTO_V2, &Capabilities::V2, 7);
        for cut in 0..caps.len() {
            assert_eq!(dec_caps_resp(&caps[..cut]), None, "truncated caps at {cut}");
        }
    }

    #[test]
    fn turn_frames_reject_garbage_magic_and_trailing_bytes() {
        let mut buf = Vec::new();
        enc_turn(&mut buf, "t", 1, &turn_batches()[1]);
        // Wrong magic byte: not a binary request at all.
        let mut garbage = buf.clone();
        garbage[0] = b'{';
        assert_eq!(dec_turn_req(&garbage), None);
        // Unknown op tag (an op-None frame ends with its tag byte).
        let mut bad_op = Vec::new();
        enc_turn(&mut bad_op, "t", 1, &TurnBatch { probes: Vec::new(), op: TurnOp::None });
        *bad_op.last_mut().unwrap() = 9;
        assert_eq!(dec_turn_req(&bad_op), None);
        // Trailing garbage is rejected by the strict decoders.
        buf.push(0xEE);
        assert_eq!(dec_turn_req(&buf), None);
        assert_eq!(dec_hello(&[MAGIC, 0x80]), None);
        assert_eq!(dec_caps_resp(&[2, 7, 7]), None);
        assert_eq!(dec_turn_resp(&[]), None);
        assert_eq!(dec_turn_resp(&[0xFF, 0xFF, 0xFF]), None);
    }

    #[test]
    fn json_bodies_never_sniff_as_binary() {
        assert!(!is_binary(b"{\"task\":\"t\"}"));
        assert!(!is_binary(b""));
        let mut buf = Vec::new();
        enc_release(&mut buf, "t", 3);
        assert!(is_binary(&buf));
    }

    fn sample_ops() -> Vec<Op> {
        use crate::cache::payload::ContentKey;
        vec![
            Op::Insert {
                task: "t".into(),
                traj: vec![(ToolCall::new("bash", "make"), ToolResult::new("ok", 1.0))],
            },
            Op::Record {
                task: "t".into(),
                node: 3,
                call: ToolCall::stateless("bash", "ls"),
                result: ToolResult { output: "a\nb".into(), exec_time: 0.25, api_tokens: 4 },
            },
            Op::Attach {
                task: "t".into(),
                node: 3,
                id: 9,
                key: ContentKey([1, 2, 3, u64::MAX]),
                bytes: Some(vec![0xDE, 0xAD, 0xBE, 0xEF].into()),
                byte_len: 4,
                serialize_cost: 0.5,
                restore_cost: 0.75,
            },
            // Dedup'd attach: content already shipped, bytes elided.
            Op::Attach {
                task: "t2".into(),
                node: 4,
                id: 10,
                key: ContentKey([5, 6, 7, 8]),
                bytes: None,
                byte_len: 1024,
                serialize_cost: 0.5,
                restore_cost: 0.75,
            },
            Op::Release { task: "t".into(), node: 5 },
            Op::WarmFork { task: "t".into(), node: 6, warm: true },
            Op::EvictSnapshot { task: "t".into(), node: 7 },
            Op::EvictNode { task: "other-task".into(), node: 8 },
        ]
    }

    #[test]
    fn replicate_batch_roundtrip_every_op_variant() {
        let ops = sample_ops();
        let mut buf = Vec::new();
        enc_replicate_resp(&mut buf, 40, 48, 4, &ops, 3);
        let got = dec_replicate_resp(&buf).unwrap();
        assert_eq!(got.start, 40);
        assert_eq!(got.next, 48);
        assert_eq!(got.shards, 4);
        assert_eq!(got.epoch, 3, "epoch rides the sealed trailer");
        assert_eq!(got.ops, ops);
        // Empty batch (follower caught up) roundtrips too.
        let mut buf = Vec::new();
        enc_replicate_resp(&mut buf, 48, 48, 4, &[], 3);
        let got = dec_replicate_resp(&buf).unwrap();
        assert!(got.ops.is_empty());
        assert_eq!((got.start, got.next), (48, 48));
    }

    #[test]
    fn replicate_frames_survive_truncation_and_garble_fuzz() {
        let mut buf = Vec::new();
        enc_replicate_resp(&mut buf, 0, 8, 1, &sample_ops(), 1);
        // Truncation at every offset: the checksum trailer makes every
        // prefix fail verification, so a half-received batch can never
        // half-apply into a follower.
        for cut in 0..buf.len() {
            assert_eq!(dec_replicate_resp(&buf[..cut]), None, "truncated at {cut}");
        }
        let mut garbled = buf.clone();
        crate::util::fault::garble(&mut garbled);
        assert_eq!(dec_replicate_resp(&garbled), None, "garbled batch must not decode");
    }

    #[test]
    fn bare_op_codec_roundtrips_and_survives_truncation() {
        // The WAL frames put_op bytes directly (no seal — its CRC32
        // framing guards integrity): the bare codec must roundtrip every
        // variant and fail cleanly on every truncation.
        for op in sample_ops() {
            let mut buf = Vec::new();
            put_op(&mut buf, &op);
            let mut r = Reader::raw(&buf);
            assert_eq!(read_op(&mut r), Some(op.clone()));
            assert!(r.done(), "strict consumption for {op:?}");
            for cut in 0..buf.len() {
                let mut r = Reader::raw(&buf[..cut]);
                if let Some(got) = read_op(&mut r) {
                    // A prefix that still decodes must be a complete
                    // shorter frame — impossible here because every field
                    // is length-prefixed, so flag it if it ever happens.
                    assert_eq!(got, op, "prefix decoded to a different op at {cut}");
                }
            }
        }
    }

    #[test]
    fn replicate_rejects_unknown_op_tags() {
        // A frame from a newer primary with op kinds this follower does
        // not know must be rejected whole, never partially applied.
        let mut buf = Vec::new();
        put_varint(&mut buf, 0); // start
        put_varint(&mut buf, 1); // next
        put_varint(&mut buf, 1); // shards
        put_varint(&mut buf, 1); // n
        let tag_at = buf.len();
        put_op(&mut buf, &Op::Release { task: "t".into(), node: 1 });
        buf[tag_at] = 0xEE;
        seal_resp(&mut buf, 1);
        assert_eq!(dec_replicate_resp(&buf), None);
    }

    #[test]
    fn resp_epoch_extracts_and_fences() {
        // Every sealed frame carries its server's epoch...
        let mut buf = Vec::new();
        enc_u64_resp(&mut buf, 42, 6);
        assert_eq!(resp_epoch(&buf), Some(6));
        assert_eq!(dec_u64_resp(&buf), Some(42));
        // ...including the handshake, so a client fences a stale primary
        // without an extra round trip.
        let mut caps = Vec::new();
        enc_caps_resp(&mut caps, Capabilities::PROTO_V2, &Capabilities::V2, 9);
        assert_eq!(resp_epoch(&caps), Some(9));
        // A frame from a revived stale primary still *verifies* — the seal
        // is integrity, not policy — but reports its lower epoch, which is
        // what the client compares against the highest epoch it has seen.
        let mut stale = Vec::new();
        enc_u64_resp(&mut stale, 42, 1);
        assert_eq!(resp_epoch(&stale), Some(1));
        assert!(resp_epoch(&stale).unwrap() < resp_epoch(&buf).unwrap());
        // Corruption anywhere — payload, epoch bytes, or checksum — kills
        // extraction (FNV-1a over payload+epoch: any single-byte flip
        // changes the sum).
        for i in 0..buf.len() {
            let mut bad = buf.clone();
            bad[i] ^= 0x40;
            assert_eq!(resp_epoch(&bad), None, "flipped byte {i}");
        }
        assert_eq!(resp_epoch(&[]), None);
        assert_eq!(resp_epoch(&buf[..RESP_TRAILER - 1]), None);
    }
}
