//! Client library (the paper's `tvclient`): cache bindings and the
//! `ToolCallExecutor` the RL training loop integrates with (Figure 4).

pub mod binding;
pub mod executor;

pub use binding::{CacheBinding, LocalBinding, RemoteBinding};
pub use executor::{CallOutcome, ExecutorConfig, ToolCallExecutor};
