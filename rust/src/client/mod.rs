//! Client library (the paper's `tvclient`): the HTTP `CacheBackend`
//! binding and the `ToolCallExecutor` the RL training loop integrates with
//! (Figure 4). Both the remote binding here and the in-process
//! [`crate::cache::ShardedCacheService`] implement the same
//! [`crate::cache::CacheBackend`] trait.

pub mod binding;
pub mod executor;

pub use binding::RemoteBinding;
pub use executor::{CallOutcome, ExecutorConfig, ToolCallExecutor};
