//! Client library (the paper's `tvclient`): the HTTP binding, the owned
//! [`RolloutSession`] handle (session API v2), and the `ToolCallExecutor`
//! the RL training loop integrates with (Figure 4). Both the remote
//! binding here and the in-process [`crate::cache::ShardedCacheService`]
//! implement the same [`crate::cache::CacheBackend`] +
//! [`crate::cache::SessionBackend`] traits.

pub mod binding;
pub mod executor;
pub mod session;

pub use binding::{BindingConfig, DrainReport, RemoteBinding};
pub use executor::{CallOutcome, ExecutorConfig, ToolCallExecutor};
pub use session::{open_session, RolloutSession, SessionConfig};
