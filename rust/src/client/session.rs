//! The owned rollout-session handle — the client half of the session API
//! v2 (`open_session` → [`RolloutSession`] → `finish()`).
//!
//! PRs 1–3 grew the cache surface into 10+ per-call methods that every
//! caller had to sequence by hand: open a cursor lazily (but only at the
//! rollout's start), step it, fall back to a full-prefix lookup on
//! `Invalid`, re-seek after the fallback, release every resume pin exactly
//! once, close the cursor at the end — and a panic anywhere leaked the
//! server-side cursor entry and any outstanding pin. `RolloutSession`
//! owns all of that: the task binding, the cursor position, and every
//! pinned snapshot/resume ref, releasing everything on [`finish`] or
//! `Drop`, so a panicking rollout can never leak server-side state.
//!
//! The handle also carries the turn-level batched hot path: with a
//! backend that negotiated [`Capabilities::turn_batch`], each
//! [`RolloutSession::step`]/[`RolloutSession::record`] ships as a single
//! `/session_turn` frame that can carry speculative stateless *probes*
//! alongside the stateful op — one wire round trip per reasoning turn
//! instead of one per lookup. Probe hits are cached locally and served
//! with zero round trips when the rollout actually issues the probed
//! call; probe misses are deliberately forgotten (trusting them could
//! diverge from a concurrent rollout's record), so batched and unbatched
//! paths make identical hit/miss decisions.
//!
//! [`finish`]: RolloutSession::finish

use std::sync::Arc;

use crate::cache::{
    CacheStats, Capabilities, CursorStep, Lookup, NodeId, SessionBackend, SnapshotCosts,
    ToolCall, ToolResult, TurnBatch, TurnOp, TurnReply,
};
use crate::sandbox::SandboxSnapshot;

/// Session knobs (mirrored from `ExecutorConfig`).
#[derive(Debug, Clone, Copy)]
pub struct SessionConfig {
    /// Use a stateful lookup cursor (the O(1) delta path). `false` keeps
    /// the whole rollout on full-prefix lookups.
    pub use_cursor: bool,
    /// Ship cursor ops as `/session_turn` batch frames when the backend
    /// advertises the capability; `false` forces the per-call cursor
    /// endpoints (the fig10 A/B baseline).
    pub batch_turns: bool,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { use_cursor: true, batch_turns: true }
    }
}

/// Open a rollout session on `backend` for `task` — the entry point of the
/// v2 API. Performs no I/O: capability negotiation and the cursor open are
/// deferred to the first lookup (and the open piggybacks on the first turn
/// frame when batching is negotiated), so a cacheless or short-circuited
/// rollout costs nothing.
pub fn open_session(
    backend: Arc<dyn SessionBackend>,
    task: impl Into<String>,
    cfg: SessionConfig,
) -> RolloutSession {
    let task = task.into();
    // Per-task generation: on a cluster router, only the group this task
    // is placed on can invalidate the session's cursor.
    let generation = backend.generation_for(&task);
    RolloutSession {
        backend,
        task,
        cfg,
        caps: None,
        cursor: 0,
        generation,
        unsupported: false,
        touched: false,
        consumed: 0,
        pins: Vec::new(),
        probe_cache: Vec::new(),
        queued_probes: Vec::new(),
        finished: false,
    }
}

/// One rollout's owned cache session. See the module docs; obtain one via
/// [`open_session`], drive it through `step`/`record`/`lookup_full`, and
/// let [`RolloutSession::finish`] (or `Drop`) tear everything down.
pub struct RolloutSession {
    backend: Arc<dyn SessionBackend>,
    /// Task id the backend routes on (§4.5 task-id sharding) — owned by
    /// the session so callers can't mix tasks mid-rollout.
    task: String,
    cfg: SessionConfig,
    /// Negotiated once on first use (the backend caches the wire handshake
    /// itself, so this is one virtual call after the first lookup).
    caps: Option<Capabilities>,
    /// Server-side session / cursor id (0 = none).
    cursor: u64,
    /// [`SessionBackend::backend_generation`] observed when the cursor was
    /// obtained. A mismatch means the binding failed over to a different
    /// server: the cursor id is meaningless there (and may collide with
    /// another rollout's), so the session drops it without closing it.
    generation: u64,
    /// Set when the backend refused a cursor (or lost one turn-open): the
    /// rollout stays on full-prefix lookups, never re-probing per call.
    unsupported: bool,
    /// Any lookup happened: a cursor may no longer be opened (a fresh one
    /// sits at the TCG root and would desynchronize from the prefix).
    touched: bool,
    /// Calls consumed so far (mirrors the executor's history length while
    /// the cursor path is in sync).
    consumed: usize,
    /// Resume-offer pins this rollout still owes a release for. Every miss
    /// path releases explicitly; whatever survives (panic, early drop) is
    /// handed back in [`RolloutSession::finish`].
    pins: Vec<NodeId>,
    /// Probe hits valid at the current session position, keyed by the
    /// probed call's fingerprint. Cleared whenever the position moves.
    probe_cache: Vec<(u64, ToolResult)>,
    /// Probes to attach to the next turn frame.
    queued_probes: Vec<ToolCall>,
    finished: bool,
}

impl RolloutSession {
    pub fn task(&self) -> &str {
        &self.task
    }

    /// Outstanding resume pins (diagnostics/tests).
    pub fn pin_count(&self) -> usize {
        self.pins.len()
    }

    /// Calls consumed through the session so far (hits + committed
    /// misses, including probe-cache hits served locally).
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// Negotiated capabilities (resolves the handshake on first call).
    /// Per-task: on a cluster router this is the answer from the group the
    /// ring places this task on, not a cluster-wide intersection.
    pub fn capabilities(&mut self) -> Capabilities {
        let (caps, backend, task) = (&mut self.caps, &self.backend, &self.task);
        *caps.get_or_insert_with(|| backend.capabilities_for(task))
    }

    /// Queue speculative stateless probes for the next turn frame.
    /// Mutating calls are ignored (probing one could never be answered
    /// without advancing state). No-op unless batching is negotiated —
    /// probes only exist to fill otherwise-idle space in a turn frame.
    pub fn queue_probes(&mut self, probes: &[ToolCall]) {
        if probes.is_empty() || !self.batched() {
            return;
        }
        self.queued_probes.extend(probes.iter().filter(|p| !p.mutates_state).cloned());
    }

    fn batched(&mut self) -> bool {
        self.cfg.use_cursor && self.cfg.batch_turns && self.capabilities().turn_batch
    }

    /// Serve a stateless call from the probe cache, if the last turn's
    /// probes covered it. Zero round trips; correctness holds because a
    /// cached stateless result at an unchanged position is exactly what a
    /// cursor step would return (values are deterministic given state, so
    /// even a concurrent eviction cannot make the served result wrong).
    ///
    /// Known skew (inherent to serving without a round trip): the server
    /// session's step count does not advance for a locally-served call, so
    /// a later miss's informational `matched_calls` — and the per-task
    /// `lookups`/`partial_hits` counters — under-count by the number of
    /// probe-served calls. Hit/miss *decisions* are unaffected, and
    /// rollouts that never pass probes (both training drivers) see
    /// byte-identical statistics to the legacy path.
    fn probe_hit(&mut self, call: &ToolCall) -> Option<ToolResult> {
        if call.mutates_state {
            return None;
        }
        let key = call.key();
        let i = self.probe_cache.iter().position(|(k, _)| *k == key)?;
        Some(self.probe_cache[i].1.clone())
    }

    fn absorb_probe_replies(&mut self, sent: &[ToolCall], replies: Vec<Option<ToolResult>>) {
        for (probe, reply) in sent.iter().zip(replies) {
            if let Some(result) = reply {
                self.probe_cache.push((probe.key(), result));
            }
        }
    }

    /// The position moved (mutating hit/record, seek, fallback): every
    /// cached probe answer was for the old position.
    fn invalidate_probes(&mut self) {
        self.probe_cache.clear();
    }

    /// Drop the cursor — without closing it — when the backend failed over
    /// to a different server since the cursor was obtained. The id was
    /// allocated by the old server; on the new one it is unknown at best
    /// and another rollout's session at worst, so stepping or closing it
    /// could hijack a stranger. The rollout continues on full-prefix
    /// lookups (new rollouts open fresh cursors on the new server).
    fn check_generation(&mut self) {
        let g = self.backend.generation_for(&self.task);
        if g != self.generation {
            self.generation = g;
            self.cursor = 0;
            self.invalidate_probes();
            self.queued_probes.clear();
        }
    }

    /// Incremental lookup of the rollout's next call — the hot path. Opens
    /// the cursor lazily on the first call (piggybacked on the turn frame
    /// when batching). `Invalid` means "use [`RolloutSession::lookup_full`]
    /// for this call"; the session re-arms itself on the follow-up
    /// [`RolloutSession::seek`].
    pub fn step(&mut self, call: &ToolCall) -> CursorStep {
        self.check_generation();
        if let Some(result) = self.probe_hit(call) {
            self.touched = true;
            self.consumed += 1;
            // Stateless by construction (only stateless calls are probed),
            // so the position is unchanged and the node id is irrelevant
            // to callers (hit handling never re-seeks).
            return CursorStep::Hit { node: 0, result };
        }
        if !self.cfg.use_cursor || self.unsupported {
            self.touched = true;
            return CursorStep::Invalid;
        }
        let opening = self.cursor == 0;
        if opening && self.touched {
            // Mid-rollout: a fresh root cursor would desync from the
            // prefix; stay on the full-prefix path.
            return CursorStep::Invalid;
        }
        self.touched = true;
        let step = if self.batched() {
            let batch = TurnBatch {
                probes: std::mem::take(&mut self.queued_probes),
                op: TurnOp::Step(call.clone()),
            };
            let reply = self.backend.session_turn(&self.task, self.cursor, &batch);
            // `apply_turn_reply` invalidates the stale probe cache (when
            // the step moved the position) *before* absorbing the reply's
            // probes, which the server evaluated at the post-step position.
            self.apply_turn_reply(&batch, reply, opening)
        } else {
            if opening {
                match self.backend.cursor_open(&self.task) {
                    0 => {
                        self.unsupported = true;
                        return CursorStep::Invalid;
                    }
                    id => self.cursor = id,
                }
            }
            let step = self.backend.cursor_step(&self.task, self.cursor, call);
            if call.mutates_state && step.is_hit() {
                // Per-call path: a mutating hit moved the position, so any
                // earlier probe answers are stale. (The cache is only ever
                // populated in batched mode, so this is belt-and-braces.)
                self.invalidate_probes();
            }
            step
        };
        match &step {
            CursorStep::Hit { .. } => {
                self.consumed += 1;
            }
            CursorStep::Miss(m) => {
                // The call is consumed either way (executed + recorded by
                // the caller); the offer's pin is now this session's debt.
                self.consumed += 1;
                if let Some((node, _, _)) = m.resume {
                    self.pins.push(node);
                }
            }
            CursorStep::Invalid => {}
        }
        step
    }

    /// Record the executed delta at the cursor and advance it. Returns the
    /// new position's node id; `None` means the record *failed* (no
    /// cursor, session refused, transport failure) and the caller should
    /// fall back to [`RolloutSession::insert_full`]. A failed record must
    /// never be released, pinned, or snapshot-attached.
    pub fn record(&mut self, call: &ToolCall, result: &ToolResult) -> Option<NodeId> {
        self.check_generation();
        if self.cursor == 0 {
            return None;
        }
        if self.batched() {
            let batch = TurnBatch {
                probes: std::mem::take(&mut self.queued_probes),
                op: TurnOp::Record(call.clone(), result.clone()),
            };
            let reply = self.backend.session_turn(&self.task, self.cursor, &batch);
            let node = reply.recorded;
            if call.mutates_state {
                self.invalidate_probes();
            }
            // Probes rode the record frame and were evaluated at the
            // post-record position — exactly where the next turn starts.
            self.absorb_turn_probes(&batch, reply);
            node
        } else {
            let node = self.backend.cursor_record(&self.task, self.cursor, call, result);
            if call.mutates_state {
                self.invalidate_probes();
            }
            node
        }
    }

    fn apply_turn_reply(
        &mut self,
        batch: &TurnBatch,
        reply: TurnReply,
        opening: bool,
    ) -> CursorStep {
        if reply.cursor == 0 {
            if opening {
                // The backend has no session support (or its table is
                // full): this rollout stays on full-prefix lookups.
                self.unsupported = true;
            }
            // Mid-rollout refusal/transport failure: keep the cursor — the
            // server entry may be fine — and fall back for this call only.
            return CursorStep::Invalid;
        }
        self.cursor = reply.cursor;
        // Destructure instead of cloning: the step payload (a hit carries
        // the full cached output string) goes straight to the caller.
        let TurnReply { probes, step, .. } = reply;
        let step = step.unwrap_or(CursorStep::Invalid);
        // A mutating step hit advanced the position: clear the stale probe
        // answers *before* absorbing this reply's, which the server
        // evaluated at the new position.
        if step.is_hit() {
            if let TurnOp::Step(call) = &batch.op {
                if call.mutates_state {
                    self.invalidate_probes();
                }
            }
        }
        self.absorb_probe_replies(&batch.probes, probes);
        step
    }

    fn absorb_turn_probes(&mut self, batch: &TurnBatch, reply: TurnReply) {
        if !batch.probes.is_empty() {
            self.absorb_probe_replies(&batch.probes, reply.probes);
        }
    }

    /// Full-prefix lookup (the legacy path / the `Invalid` fallback). A
    /// miss's resume pin becomes session debt like any other.
    pub fn lookup_full(&mut self, q: &[ToolCall]) -> Lookup {
        self.touched = true;
        self.invalidate_probes();
        let out = self.backend.lookup(&self.task, q);
        if let Lookup::Miss(m) = &out {
            if let Some((node, _, _)) = m.resume {
                self.pins.push(node);
            }
        }
        out
    }

    /// Full-trajectory insert, then re-seat the cursor on the returned
    /// node. `None` means the insert never reached the backend (transport
    /// failure): the rollout's output is unaffected, the trajectory is
    /// just not cached.
    pub fn insert_full(&mut self, traj: &[(ToolCall, ToolResult)]) -> Option<NodeId> {
        self.touched = true;
        let node = self.backend.insert(&self.task, traj)?;
        if node != 0 {
            self.seek(node, traj.len());
        }
        Some(node)
    }

    /// Whether the backend is currently degraded (circuit breaker open on
    /// a remote binding): the executor short-circuits cache traffic to
    /// plain execution while this holds. Per-task: a cluster router with
    /// one broken group is degraded only for the tasks placed there.
    pub fn degraded(&self) -> bool {
        self.backend.degraded_for(&self.task)
    }

    /// Re-seat the cursor after a fallback re-established the position.
    ///
    /// A failed seek usually means the server swept this session (idle
    /// longer than its TTL — a stalled rollout that came back): recover by
    /// opening a fresh cursor and seating it directly on `node`, so the
    /// rest of the rollout returns to the O(1) path instead of paying a
    /// wasted `Invalid` round trip plus a full-prefix lookup per call. If
    /// even the fresh cursor cannot be seated (the node died in between),
    /// the session goes cursorless — a root-parked cursor must never be
    /// stepped mid-rollout — and the rollout stays on full-prefix lookups.
    /// Correctness never depends on the seek.
    pub fn seek(&mut self, node: NodeId, steps: usize) {
        self.check_generation();
        self.invalidate_probes();
        if self.cursor == 0 {
            return;
        }
        if self.backend.cursor_seek(&self.task, self.cursor, node, steps) {
            self.consumed = steps;
            return;
        }
        // Cursor unknown server-side (swept) or the node is gone: replace
        // it. Executor flows hold no outstanding offer pins at seek time
        // (every miss path releases before recording), so closing the old
        // entry releases nothing the client still owes.
        self.backend.cursor_close(&self.task, self.cursor);
        self.cursor = 0;
        let fresh = self.backend.cursor_open(&self.task);
        if fresh == 0 {
            return; // cursorless: full-prefix for the rest of the rollout
        }
        if self.backend.cursor_seek(&self.task, fresh, node, steps) {
            self.cursor = fresh;
            self.consumed = steps;
        } else {
            self.backend.cursor_close(&self.task, fresh);
        }
    }

    /// Hand back one resume pin (the rollout is done with the offer).
    pub fn release(&mut self, node: NodeId) {
        self.check_generation();
        if let Some(i) = self.pins.iter().position(|&p| p == node) {
            self.pins.swap_remove(i);
        }
        self.backend.session_release(&self.task, self.cursor, node);
    }

    // ---- task-scoped pass-throughs (the executor's miss path) ----

    pub fn should_snapshot(&self, costs: SnapshotCosts) -> bool {
        self.backend.should_snapshot(&self.task, costs)
    }

    pub fn store_snapshot(&self, node: NodeId, snap: SandboxSnapshot) -> u64 {
        self.backend.store_snapshot(&self.task, node, snap)
    }

    pub fn fetch_snapshot(&self, id: u64) -> Option<SandboxSnapshot> {
        self.backend.fetch_snapshot(&self.task, id)
    }

    pub fn set_warm_fork(&self, node: NodeId, warm: bool) {
        self.backend.set_warm_fork(&self.task, node, warm);
    }

    pub fn has_warm_fork(&self, node: NodeId) -> bool {
        self.backend.has_warm_fork(&self.task, node)
    }

    pub fn stats(&self) -> CacheStats {
        self.backend.stats(&self.task)
    }

    /// Rollout finished: release every outstanding pin and close the
    /// cursor (dropping the server-side session entry, which releases any
    /// pins *it* still tracks). Idempotent; `Drop` calls it, so a leaked
    /// or panicking rollout tears down exactly like a finished one.
    pub fn finish(&mut self) {
        if self.finished {
            return;
        }
        self.finished = true;
        // A failover since the cursor was obtained makes its id unsafe to
        // close (it may be another rollout's session on the new server).
        self.check_generation();
        for node in std::mem::take(&mut self.pins) {
            self.backend.session_release(&self.task, self.cursor, node);
        }
        if self.cursor != 0 {
            self.backend.cursor_close(&self.task, self.cursor);
            self.cursor = 0;
        }
        self.probe_cache.clear();
        self.queued_probes.clear();
    }
}

impl Drop for RolloutSession {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheBackend, ShardedCacheService};
    use crate::sandbox::SandboxSnapshot;

    const TASK: &str = "session-task";

    fn sf(s: &str) -> ToolCall {
        ToolCall::new("t", s)
    }

    fn sl(s: &str) -> ToolCall {
        ToolCall::stateless("t", s)
    }

    fn warm_service() -> (Arc<ShardedCacheService>, NodeId) {
        let svc = Arc::new(ShardedCacheService::new(2));
        let traj: Vec<(ToolCall, ToolResult)> = ["a", "b"]
            .iter()
            .map(|c| (sf(c), ToolResult::new(format!("out-{c}"), 1.0)))
            .collect();
        let node = svc.insert(TASK, &traj).unwrap();
        let snap =
            SandboxSnapshot { bytes: vec![1u8; 16], serialize_cost: 0.1, restore_cost: 0.2 };
        assert!(svc.store_snapshot(TASK, node, snap) > 0);
        (svc, node)
    }

    fn open(svc: &Arc<ShardedCacheService>, cfg: SessionConfig) -> RolloutSession {
        open_session(Arc::clone(svc) as Arc<dyn SessionBackend>, TASK, cfg)
    }

    #[test]
    fn dropped_session_releases_cursor_and_pins() {
        let (svc, _) = warm_service();
        let mut s = open(&svc, SessionConfig::default());
        assert!(s.step(&sf("a")).is_hit());
        assert!(s.step(&sf("b")).is_hit());
        // Divergent step: miss with a pinned resume offer the rollout
        // never releases (models a panic mid-miss).
        assert!(matches!(s.step(&sf("zz")), CursorStep::Miss(_)));
        assert_eq!(s.pin_count(), 1);
        assert_eq!(svc.session_count(), 1);
        assert_eq!(svc.task(TASK).pinned_node_count(), 1);
        drop(s); // no finish(): the Drop guard must tear everything down
        assert_eq!(svc.session_count(), 0, "leaked session entry");
        assert_eq!(svc.task(TASK).pinned_node_count(), 0, "leaked resume pin");
    }

    #[test]
    fn finish_is_idempotent_and_explicit_release_prevents_double_free() {
        let (svc, node) = warm_service();
        let mut s = open(&svc, SessionConfig::default());
        assert!(s.step(&sf("a")).is_hit());
        assert!(s.step(&sf("b")).is_hit());
        let CursorStep::Miss(m) = s.step(&sf("zz")) else { panic!("expected miss") };
        let (rnode, _, _) = m.resume.expect("snapshot offered");
        assert_eq!(rnode, node);
        s.release(rnode);
        assert_eq!(s.pin_count(), 0);
        assert_eq!(svc.task(TASK).pinned_node_count(), 0);
        // A second rollout pins the same node; our finish must not steal it.
        let mut other = open(&svc, SessionConfig::default());
        assert!(other.step(&sf("a")).is_hit());
        assert!(other.step(&sf("b")).is_hit());
        assert!(matches!(other.step(&sf("yy")), CursorStep::Miss(_)));
        assert_eq!(svc.task(TASK).pinned_node_count(), 1);
        s.finish();
        s.finish();
        assert_eq!(
            svc.task(TASK).pinned_node_count(),
            1,
            "finish of a pin-free session must not release another rollout's pin"
        );
        drop(other);
        assert_eq!(svc.task(TASK).pinned_node_count(), 0);
    }

    #[test]
    fn probe_hit_served_locally_and_invalidated_on_mutation() {
        let svc = Arc::new(ShardedCacheService::new(2));
        // Warm: a (mutating) then stateless reads indexed on it.
        svc.insert(
            TASK,
            &[
                (sf("a"), ToolResult::new("out-a", 1.0)),
                (sl("cat x"), ToolResult::new("x-contents", 0.1)),
            ],
        );
        let mut s = open(&svc, SessionConfig::default());
        s.queue_probes(&[sl("cat x"), sl("cat missing")]);
        assert!(s.step(&sf("a")).is_hit(), "probes ride the step frame");
        let lookups_before = svc.stats(TASK).lookups;
        // The probed stateless call is served locally: no backend lookup.
        match s.step(&sl("cat x")) {
            CursorStep::Hit { result, .. } => assert_eq!(result.output, "x-contents"),
            step => panic!("probe-covered call must hit locally: {step:?}"),
        }
        assert_eq!(
            svc.stats(TASK).lookups,
            lookups_before,
            "a probe-cache hit must not issue a backend lookup"
        );
        // The un-probed miss still goes to the backend (probe misses are
        // never trusted).
        assert!(matches!(s.step(&sl("cat missing")), CursorStep::Miss(_)));
        assert_eq!(s.consumed(), 3, "probe-served hits count as consumed calls");
    }

    #[test]
    fn cursorless_config_stays_on_full_prefix_path() {
        let (svc, _) = warm_service();
        let mut s =
            open(&svc, SessionConfig { use_cursor: false, batch_turns: true });
        assert_eq!(s.step(&sf("a")), CursorStep::Invalid);
        assert!(s.lookup_full(&[sf("a")]).is_hit());
        assert_eq!(svc.session_count(), 0, "cursorless session must not open one");
        s.finish();
    }
}
