//! The `ToolCallExecutor` (Figure 4): the client-side loop the RL framework
//! integrates with.
//!
//! One executor serves one rollout, through one owned
//! [`RolloutSession`]: the session holds the rollout's pinned TCG
//! position (its lookup cursor) plus every resume pin, so each tool call
//! costs one O(1) delta step — a single `/session_turn` frame per
//! reasoning turn on a turn-batch backend — instead of serializing the
//! full history, with a transparent fall-back to the full-prefix lookup
//! when the backend lacks cursors or eviction invalidates one. On a hit it
//! returns the cached value at cache-get latency. On a miss it
//! reconstructs the needed sandbox state — preferring, in order: the live
//! sandbox it already owns (when up-to-date), a forked snapshot from the
//! LPM resume point, catch-up replay in its live sandbox, and finally a
//! fresh root sandbox with full replay (the paper's §3.2 fallback) — then
//! executes the call, records the extended trajectory (the delta through
//! the cursor), and applies the §3.3 selective-snapshot rule.
//!
//! The returned [`CallOutcome::charged`] is the latency the rollout *waits*,
//! which the virtual-clock experiments charge to simulated time: cache-get
//! latency on hits; fork/replay/execute/serialize costs on misses.

use std::sync::Arc;

use super::session::{open_session, RolloutSession, SessionConfig};
use crate::cache::{CursorStep, Lookup, Miss, SessionBackend, SnapshotCosts, ToolCall, ToolResult};
use crate::sandbox::{SandboxFactory, ToolExecutionEnvironment};

/// Executor tunables (defaults match the paper's measured constants).
#[derive(Debug, Clone, Copy)]
pub struct ExecutorConfig {
    /// Master switch: `false` = the cacheless baseline.
    pub enabled: bool,
    /// Cache lookup round-trip (paper: P95 3.3 ms at 256 RPS).
    pub cache_get_latency: f64,
    /// Attaching a pre-forked (warm) sandbox (§3.3 proactive forking).
    pub warm_fork_attach: f64,
    /// Warm root-sandbox pool: hides container start-up at rollout begin.
    pub proactive_roots: bool,
    /// Mark snapshots warm via background instantiation (§3.3).
    pub background_forks: bool,
    /// Must mirror the server's `LpmConfig::stateful_filtering`: decides how
    /// a resume node's TCG depth maps back to a query index.
    pub stateful_filtering: bool,
    /// Use a stateful lookup cursor: each lookup/record sends only the
    /// *delta* call (O(1) per tool call) instead of the full history.
    /// Falls back to full-prefix lookups transparently when the backend
    /// does not support cursors or a cursor is invalidated by eviction.
    pub use_cursor: bool,
    /// Ship cursor ops as single `/session_turn` batch frames (probes +
    /// one stateful op per reasoning turn) when the backend negotiated the
    /// capability; `false` forces the per-call cursor endpoints.
    pub batch_turns: bool,
    /// Contention multiplier on cold sandbox start/stop (cacheless runs
    /// create B·R containers concurrently at step start; Figure 13 shows
    /// the baseline manager's throughput collapse under that load).
    pub cold_start_factor: f64,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            enabled: true,
            cache_get_latency: 0.0033,
            warm_fork_attach: 0.05,
            proactive_roots: true,
            background_forks: true,
            stateful_filtering: true,
            use_cursor: true,
            batch_turns: true,
            cold_start_factor: 1.0,
        }
    }
}

impl ExecutorConfig {
    pub fn cacheless() -> Self {
        ExecutorConfig { enabled: false, ..Default::default() }
    }
}

/// Outcome of one tool call through the executor.
#[derive(Debug, Clone)]
pub struct CallOutcome {
    pub result: ToolResult,
    /// Seconds the rollout waited for this call (what Figures 2/7/14 plot).
    pub charged: f64,
    pub hit: bool,
}

/// Per-rollout executor. One executor serves one rollout of one task; the
/// backend (in-process sharded service or HTTP binding) is shared across
/// every concurrent rollout.
pub struct ToolCallExecutor {
    /// The rollout's owned cache session: task binding + cursor + pinned
    /// resume refs, all torn down on `finish()` or `Drop` (a panicking
    /// rollout can no longer leak a server-side cursor entry or pin).
    session: RolloutSession,
    factory: Arc<dyn SandboxFactory>,
    task_seed: u64,
    cfg: ExecutorConfig,
    history: Vec<(ToolCall, ToolResult)>,
    sandbox: Option<Box<dyn ToolExecutionEnvironment>>,
    /// `history[..valid_upto]` is reflected in the live sandbox's state.
    valid_upto: usize,
    /// Total charged seconds (incl. start/stop overheads).
    pub total_charged: f64,
    pub hits: u64,
    pub misses: u64,
}

impl ToolCallExecutor {
    pub fn new(
        backend: Arc<dyn SessionBackend>,
        task: impl Into<String>,
        factory: Arc<dyn SandboxFactory>,
        task_seed: u64,
        cfg: ExecutorConfig,
    ) -> ToolCallExecutor {
        let session = open_session(
            backend,
            task,
            SessionConfig { use_cursor: cfg.use_cursor, batch_turns: cfg.batch_turns },
        );
        ToolCallExecutor {
            session,
            factory,
            task_seed,
            cfg,
            history: Vec::new(),
            sandbox: None,
            valid_upto: 0,
            total_charged: 0.0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn history(&self) -> &[(ToolCall, ToolResult)] {
        &self.history
    }

    /// Execute one tool call (the RL loop's integration point).
    pub fn call(&mut self, call: ToolCall) -> CallOutcome {
        self.call_with_probes(call, &[])
    }

    /// Execute one tool call, batching speculative stateless `probes` into
    /// the same turn frame (the agent's guesses at its next read-only
    /// calls). Probe hits are served locally by the session on later
    /// calls; probe misses are ignored, so hit/miss decisions are
    /// identical with or without probes.
    pub fn call_with_probes(&mut self, call: ToolCall, probes: &[ToolCall]) -> CallOutcome {
        let outcome = if !self.cfg.enabled {
            self.call_direct(call)
        } else if self.session.degraded() {
            // Circuit breaker open (cache unreachable): bypass all cache
            // traffic for this call. Not `call_direct` — earlier cache hits
            // may have left the live sandbox behind the history, so the
            // degraded path still runs the state-reconstruction machinery.
            self.call_degraded(call)
        } else {
            self.session.queue_probes(probes);
            self.call_cached(call)
        };
        self.total_charged += outcome.charged;
        outcome
    }

    /// Rollout finished: tear down the live sandbox (charged; the paper's
    /// Appendix F attributes much of the baseline's cost to start/stop)
    /// and finish the session (cursor close + pin release).
    pub fn finish(&mut self) -> f64 {
        self.session.finish();
        let mut charged = 0.0;
        if let Some(mut sb) = self.sandbox.take() {
            // With proactive management the stop happens off the rollout's
            // critical path (background cleanup).
            let stop = sb.stop();
            if !self.cfg.enabled || !self.cfg.proactive_roots {
                charged += stop * self.cfg.cold_start_factor;
            }
        }
        self.total_charged += charged;
        charged
    }

    // -- cacheless baseline ------------------------------------------------

    fn call_direct(&mut self, call: ToolCall) -> CallOutcome {
        self.misses += 1; // every cacheless call executes for real
        let mut charged = 0.0;
        if self.sandbox.is_none() {
            let mut sb = self.factory.create(self.task_seed);
            // Cold container start on the critical path, amplified by the
            // concurrent-creation contention of a full batch (Appendix E).
            charged += sb.start() * self.cfg.cold_start_factor;
            self.sandbox = Some(sb);
        }
        let result = self.sandbox.as_mut().unwrap().execute(&call);
        charged += result.exec_time;
        self.history.push((call, result.clone()));
        self.valid_upto = self.history.len();
        CallOutcome { result, charged, hit: false }
    }

    // -- degraded path (breaker open) ----------------------------------------

    /// Execute with zero cache traffic but full state reconstruction:
    /// catch-up replay brings the live (or a fresh) sandbox to the state
    /// implied by the history — which may contain cache hits from before
    /// the breaker opened — then the call runs for real. Nothing is
    /// looked up, recorded, or snapshotted; the rollout's outputs are
    /// identical to a cacheless run of the same trajectory.
    fn call_degraded(&mut self, call: ToolCall) -> CallOutcome {
        self.misses += 1;
        let synthetic = Miss {
            matched_node: 0,
            matched_calls: self.history.len(),
            resume: None,
        };
        let mut charged = self.ensure_state(&synthetic);
        let sb = self.sandbox.as_mut().expect("ensure_state built a sandbox");
        let result = sb.execute(&call);
        charged += result.exec_time;
        self.history.push((call, result.clone()));
        self.valid_upto = self.history.len();
        CallOutcome { result, charged, hit: false }
    }

    // -- cached path ---------------------------------------------------------

    fn call_cached(&mut self, call: ToolCall) -> CallOutcome {
        let charged = self.cfg.cache_get_latency;

        // Hot path: one O(1) session step carrying only the delta call —
        // no full-history clone, no O(L) wire payload, and (with a
        // negotiated turn-batch backend) one wire frame for the whole
        // reasoning turn. The session opens its cursor lazily on the first
        // call and handles the unsupported/mid-rollout cases by reporting
        // `Invalid`, which lands on the full-prefix path below.
        match self.session.step(&call) {
            CursorStep::Hit { node: _, result } => {
                self.hits += 1;
                self.history.push((call, result.clone()));
                // Live sandbox (if any) now lags history; `valid_upto`
                // already reflects that.
                return CallOutcome { result, charged, hit: true };
            }
            CursorStep::Miss(miss) => {
                return self.execute_miss(call, &miss, charged, true);
            }
            CursorStep::Invalid => {
                // The cursor's node was evicted, the transport hiccuped,
                // or the backend has no cursor support: fall through to
                // the full-prefix path, which re-seeks afterwards.
            }
        }

        // Full-prefix (legacy / fallback) path.
        let mut q: Vec<ToolCall> = self.history.iter().map(|(c, _)| c.clone()).collect();
        q.push(call.clone());
        match self.session.lookup_full(&q) {
            Lookup::Hit { node, result } => {
                self.hits += 1;
                self.history.push((call, result.clone()));
                // A mutating hit's node — or a stateless hit's parent — is
                // exactly the rollout's TCG position: re-seat the cursor.
                self.session.seek(node, self.history.len());
                CallOutcome { result, charged, hit: true }
            }
            Lookup::Miss(miss) => self.execute_miss(call, &miss, charged, false),
        }
    }

    /// The shared miss path: reconstruct state, execute, record the
    /// extended trajectory (through the cursor when `record_delta`, else a
    /// full `/put`), and apply the §3.3 selective-snapshot rule.
    fn execute_miss(
        &mut self,
        call: ToolCall,
        miss: &Miss,
        mut charged: f64,
        record_delta: bool,
    ) -> CallOutcome {
        self.misses += 1;
        charged += self.ensure_state(miss);
        let sb = self.sandbox.as_mut().expect("ensure_state built a sandbox");
        let result = sb.execute(&call);
        charged += result.exec_time;
        self.history.push((call.clone(), result.clone()));
        self.valid_upto = self.history.len();

        // Record the extended trajectory (the /put of Figure 4). With an
        // in-sync cursor only the delta crosses the wire; a *failed* delta
        // record (`None`: cursor invalidated between step and record, or
        // the transport died) falls back to the full-trajectory insert and
        // re-seeks. Caveat: `Some(0)` is the *legitimate* return for a
        // stateless delta recorded at the TCG root (an all-stateless
        // history pins the cursor at ROOT) — but a legacy remote server
        // also encodes failure as 0 on the wire, so `Some(0)` is only
        // trusted when the position can actually be ROOT.
        let root_legal = !call.mutates_state
            && !self.history[..self.history.len() - 1]
                .iter()
                .any(|(c, _)| c.mutates_state);
        let node = if record_delta {
            match self.session.record(&call, &result) {
                None => self.insert_full_and_reseek(),
                Some(0) if !root_legal => self.insert_full_and_reseek(),
                some => some,
            }
        } else {
            self.insert_full_and_reseek()
        };

        // §3.3 selective snapshotting, on the critical path; the fork
        // instantiation happens in the background. A failed record/insert
        // (`None` — the remote lost the network) or the ROOT sentinel (0)
        // must never be snapshot-attached: attaching this sandbox's deep
        // state there would let later rollouts resume wrong state.
        if call.mutates_state {
            if let Some(node) = node.filter(|&n| n != 0) {
                let sb = self.sandbox.as_ref().unwrap();
                let snap = sb.snapshot();
                let costs = SnapshotCosts {
                    exec_time: result.exec_time,
                    serialize_cost: snap.serialize_cost,
                    restore_cost: snap.restore_cost,
                };
                if self.session.should_snapshot(costs) {
                    charged += snap.serialize_cost;
                    // id 0 = the store rejected the attach (node pinned
                    // or evicted concurrently): no snapshot was kept,
                    // so there is nothing to background-fork.
                    let id = self.session.store_snapshot(node, snap);
                    if id != 0 && self.cfg.background_forks {
                        self.session.set_warm_fork(node, true);
                    }
                }
            }
        }
        CallOutcome { result, charged, hit: false }
    }

    /// Full-trajectory insert through the session, which re-seats the
    /// cursor on the returned node. `None` = the insert never reached the
    /// backend (transport failure).
    fn insert_full_and_reseek(&mut self) -> Option<usize> {
        self.session.insert_full(&self.history)
    }

    /// Bring `self.sandbox` to the state implied by the current history
    /// (the prefix of the call being missed). Returns the charged
    /// reconstruction latency.
    ///
    /// A miss with a resume offer arrives with the resume node *pinned*
    /// (§3.4 Concurrency Control): every path below either adopts the
    /// snapshot (adopt_snapshot releases after forking) or explicitly hands
    /// the pin back — a leaked pin would block eviction of that snapshot
    /// forever.
    fn ensure_state(&mut self, miss: &Miss) -> f64 {
        let prefix_len = self.history.len();

        // Fast path: the live sandbox is already up to date. The lookup
        // still pinned the resume node; return the pin unused.
        if self.sandbox.is_some() && self.valid_upto == prefix_len {
            if let Some((node, _, _)) = miss.resume {
                self.session.release(node);
            }
            return 0.0;
        }

        // Option B's starting point: catch-up replay in the live sandbox.
        let live_start = if self.sandbox.is_some() { Some(self.valid_upto) } else { None };

        // Option A: fork the snapshot the LPM offered. `replay_from` is the
        // resume node's stateful depth; map it to a history index. The plan
        // is decided *before* fetching, so a live sandbox that is already
        // at/ahead of the snapshot — or a snapshot whose restore (possibly
        // a disk fault-in from the spill tier) costs more than the replay
        // it skips — never pays the payload transfer.
        let snapshot_plan = miss.resume.and_then(|(node, snap, depth)| {
            let idx = if self.cfg.stateful_filtering {
                // Clamp: a malformed remote offer must never index past the
                // prefix the rollout actually executed.
                depth_to_index(
                    self.history.iter().map(|(c, _)| c.mutates_state),
                    depth,
                    prefix_len,
                )
                .min(prefix_len)
            } else {
                depth.min(prefix_len)
            };
            let replay_start = live_start.unwrap_or(0);
            if replay_start >= idx {
                // The snapshot cannot skip any replay work: keep what we
                // have, return the pin unused.
                self.session.release(node);
                return None;
            }
            // Seconds of replay the snapshot saves: the recorded latencies
            // of the state-mutating calls it covers. Adopt only when the
            // restore beats that — unless a warm background fork makes the
            // attach nearly free (§3.3). The estimate uses the ref's
            // recorded restore cost; a spilled payload pays a small extra
            // disk fault-in at fetch time that the plan ignores (the offer
            // does not reveal spilled-ness, and the penalty is ~ms-scale
            // against multi-second replay savings).
            let saved: f64 = self.history[replay_start..idx]
                .iter()
                .filter(|(c, _)| c.mutates_state)
                .map(|(_, r)| r.exec_time)
                .sum();
            if snap.restore_cost >= saved && !self.session.has_warm_fork(node)
            {
                self.session.release(node);
                return None;
            }
            match self.session.fetch_snapshot(snap.id) {
                Some(s) => Some((node, s, idx)),
                None => {
                    // Snapshot gone (evicted / transport failure): the pin
                    // from the lookup must still be returned.
                    self.session.release(node);
                    None
                }
            }
        });

        // Option C: fresh sandbox, full replay.
        let mut charged = 0.0;
        let replay_start = match (snapshot_plan, live_start) {
            (Some((node, snap, idx)), _) => {
                // Snapshot gets us at least as far as any live sandbox.
                charged += self.adopt_snapshot(node, snap);
                idx
            }
            (None, Some(live)) => live, // keep the live sandbox, replay delta
            (None, None) => {
                let mut sb = self.factory.create(self.task_seed);
                let start = sb.start();
                if !self.cfg.proactive_roots {
                    charged += start; // warm root pool hides this otherwise
                }
                self.sandbox = Some(sb);
                0
            }
        };

        // Replay the state-mutating calls in history[replay_start..].
        let sb = self.sandbox.as_mut().unwrap();
        for (call, _) in &self.history[replay_start..prefix_len] {
            if call.mutates_state {
                let r = sb.execute(call);
                charged += r.exec_time;
            }
        }
        self.valid_upto = prefix_len;
        charged
    }

    fn adopt_snapshot(
        &mut self,
        node: usize,
        snap: crate::sandbox::SandboxSnapshot,
    ) -> f64 {
        let charged = if self.session.has_warm_fork(node) {
            // §3.3 reactive forking found a background-instantiated copy.
            self.session.set_warm_fork(node, false);
            self.cfg.warm_fork_attach
        } else {
            snap.restore_cost
        };
        self.sandbox = Some(self.factory.restore(&snap));
        self.session.release(node);
        charged
    }
}

/// Index in `q` just *after* the `depth`-th state-mutating call.
pub fn stateful_depth_to_index(q: &[ToolCall], depth: usize) -> usize {
    depth_to_index(q.iter().map(|c| c.mutates_state), depth, q.len())
}

/// Shared core of [`stateful_depth_to_index`] over any mutates-flag
/// sequence (the executor iterates its history pairs without cloning).
fn depth_to_index(flags: impl Iterator<Item = bool>, depth: usize, len: usize) -> usize {
    if depth == 0 {
        return 0;
    }
    let mut seen = 0;
    for (i, mutates) in flags.enumerate() {
        if mutates {
            seen += 1;
            if seen == depth {
                return i + 1;
            }
        }
    }
    len
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheBackend, ShardedCacheService};
    use crate::sandbox::TerminalFactory;

    const TASK: &str = "task-under-test";

    fn svc() -> Arc<ShardedCacheService> {
        Arc::new(ShardedCacheService::new(2))
    }

    fn make(
        backend: Arc<ShardedCacheService>,
        cfg: ExecutorConfig,
        seed: u64,
    ) -> ToolCallExecutor {
        let factory = Arc::new(TerminalFactory { medium: false });
        ToolCallExecutor::new(backend, TASK, factory, seed, cfg)
    }

    fn bash(cmd: &str) -> ToolCall {
        let mutates = !(cmd.starts_with("cat") || cmd.starts_with("ls") || cmd.starts_with("grep"));
        ToolCall::with_flag("bash", cmd, mutates)
    }

    #[test]
    fn second_rollout_hits_first_rollouts_calls() {
        let cache = svc();
        let cmds = ["pip install libdep1", "make", "make test"];

        let mut r1 = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        for c in cmds {
            let o = r1.call(bash(c));
            assert!(!o.hit);
        }
        let mut r2 = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        for c in cmds {
            let o = r2.call(bash(c));
            assert!(o.hit, "expected hit for {c}");
            assert!(o.charged < 0.01, "hit should cost ~get latency");
        }
        assert_eq!(r2.hits, 3);
    }

    #[test]
    fn hit_returns_identical_output_to_uncached_execution() {
        // The paper's correctness claim, end-to-end: cached rollout output
        // must equal a fresh cacheless execution of the same trajectory.
        let cmds = [
            "echo v1 > cfg.txt",
            "cat cfg.txt",
            "patch src/module_1.py s/return x - 2/return x + 2/",
            "make",
            "cat cfg.txt",
        ];
        let cache = svc();
        let mut warm = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        let warm_out: Vec<String> =
            cmds.iter().map(|c| warm.call(bash(c)).result.output).collect();

        let mut cached = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        let cached_out: Vec<String> =
            cmds.iter().map(|c| cached.call(bash(c)).result.output).collect();

        let mut baseline = make(svc(), ExecutorConfig::cacheless(), 1);
        let base_out: Vec<String> =
            cmds.iter().map(|c| baseline.call(bash(c)).result.output).collect();

        assert_eq!(cached_out, base_out);
        assert_eq!(warm_out, base_out);
    }

    #[test]
    fn stateful_divergence_never_serves_stale_value() {
        // §1 example: rollout B patches differently, then cats — must see
        // its own patch, not rollout A's cached cat.
        let cache = svc();
        let f = "src/module_1.py";
        let mut a = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        a.call(bash(&format!("patch {f} s/return x - 2/return x + 2/")));
        let a_cat = a.call(bash(&format!("cat {f}"))).result.output;

        let mut b = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        b.call(bash(&format!("patch {f} s/return x - 2/return x * 9/")));
        let b_cat = b.call(bash(&format!("cat {f}"))).result.output;

        assert_ne!(a_cat, b_cat);
        assert!(b_cat.contains("x * 9"), "{b_cat}");
    }

    #[test]
    fn miss_after_hits_reconstructs_state_correctly() {
        let cache = svc();
        let mut a = make(Arc::clone(&cache), ExecutorConfig::default(), 2);
        for c in ["echo alpha > f1", "echo beta > f2", "make"] {
            a.call(bash(c));
        }
        // Rollout B hits on all three, then diverges with a read of f1.
        let mut b = make(Arc::clone(&cache), ExecutorConfig::default(), 2);
        for c in ["echo alpha > f1", "echo beta > f2", "make"] {
            assert!(b.call(bash(c)).hit);
        }
        let out = b.call(bash("cat f1")).result.output;
        assert_eq!(out, "alpha");
    }

    #[test]
    fn cacheless_never_hits_and_charges_start() {
        let mut x = make(svc(), ExecutorConfig::cacheless(), 3);
        let o = x.call(bash("cat README.md"));
        assert!(!o.hit);
        // Charged includes the 4 s container start.
        assert!(o.charged > 3.9, "charged {}", o.charged);
        let o2 = x.call(bash("ls"));
        assert!(o2.charged < 1.0, "second call reuses the container");
        let stop = x.finish();
        assert!(stop > 0.0);
    }

    #[test]
    fn snapshot_resume_cheaper_than_full_replay() {
        // Build an expensive prefix (make test ⇒ snapshotted), then a new
        // rollout diverges after it: resume must avoid re-running the build.
        let cache = svc();
        let mut a = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        a.call(bash("pip install libdep1"));
        a.call(bash("make"));
        a.call(bash("make test")); // expensive ⇒ snapshot stored
        assert!(cache.task(TASK).snapshot_count() > 0, "expensive calls must snapshot");

        let mut b = make(cache, ExecutorConfig::default(), 1);
        for c in ["pip install libdep1", "make", "make test"] {
            assert!(b.call(bash(c)).hit);
        }
        // Divergent cheap call: state comes from the snapshot fork, so the
        // charge must be far below re-running install+make+test (~20 s).
        let o = b.call(bash("echo done > status.txt"));
        assert!(!o.hit);
        assert!(o.charged < 5.0, "resume too expensive: {}", o.charged);
    }

    #[test]
    fn miss_paths_release_resume_pins() {
        // Every miss path must hand the lookup's resume pin back — a
        // leaked pin blocks snapshot eviction forever (§3.4).
        let cache = svc();
        let mut a = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        a.call(bash("pip install libdep1"));
        a.call(bash("make"));
        a.call(bash("make test")); // expensive ⇒ snapshot stored
        assert!(cache.task(TASK).snapshot_count() > 0);
        // Same rollout continues: its live sandbox is up to date, so these
        // divergent misses take the fast path — pins must still come back.
        a.call(bash("echo more >> log.txt"));
        a.call(bash("echo again >> log.txt"));
        assert_eq!(cache.task(TASK).pinned_node_count(), 0, "fast path leaked a pin");

        // Fresh rollout: hits the prefix, then diverges via the snapshot
        // fork (adopt path releases after forking).
        let mut b = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        for c in ["pip install libdep1", "make", "make test"] {
            assert!(b.call(bash(c)).hit);
        }
        b.call(bash("echo done > status.txt"));
        assert_eq!(cache.task(TASK).pinned_node_count(), 0, "adopt path leaked a pin");
    }

    #[test]
    fn executor_trajectories_equal_across_hit_and_miss_paths() {
        // Property: for any trajectory, state fingerprint after cached
        // replays equals the baseline fingerprint (tested via outputs of a
        // trailing `cat`+`make test`).
        let cmds = [
            "pip install libdep1",
            "make",
            "patch src/module_1.py s/return x - 2/return x + 2/",
            "make",
            "make test",
        ];
        let cache = svc();
        for seed_rollout in 0..3 {
            let mut e = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
            let outs: Vec<String> =
                cmds.iter().map(|c| e.call(bash(c)).result.output).collect();
            assert!(
                outs.last().unwrap().contains("12 passed"),
                "rollout {seed_rollout}: {outs:?}"
            );
        }
    }

    #[test]
    fn expensive_restore_rejected_in_favour_of_replay() {
        // Cost-aware resume planning: a snapshot whose restore (e.g. a
        // deep-spilled payload) costs more than the replay it skips is not
        // adopted — the executor replays and still returns the pin.
        let cache = svc();
        let node = cache
            .insert(
                TASK,
                &[(
                    bash("make"),
                    ToolResult { output: "built".into(), exec_time: 9.0, api_tokens: 0 },
                )],
            )
            .unwrap();
        let huge = crate::sandbox::SandboxSnapshot {
            bytes: vec![0u8; 8],
            serialize_cost: 0.1,
            restore_cost: 1e6,
        };
        assert!(cache.store_snapshot(TASK, node, huge) > 0);

        let mut e = make(Arc::clone(&cache), ExecutorConfig::default(), 1);
        assert!(e.call(bash("make")).hit);
        let o = e.call(bash("echo done > status.txt"));
        assert!(!o.hit);
        assert!(
            o.charged < 1000.0,
            "restore (1e6 s) must have been rejected for replay: {}",
            o.charged
        );
        assert_eq!(cache.task(TASK).pinned_node_count(), 0, "rejection leaked the pin");
    }

    #[test]
    fn stateful_depth_mapping() {
        let q = vec![
            bash("make"),          // mutating (depth 1)
            bash("cat a"),         // stateless
            bash("echo x > f"),    // mutating (depth 2)
            bash("ls"),            // stateless
        ];
        assert_eq!(stateful_depth_to_index(&q, 0), 0);
        assert_eq!(stateful_depth_to_index(&q, 1), 1);
        assert_eq!(stateful_depth_to_index(&q, 2), 3);
        assert_eq!(stateful_depth_to_index(&q, 5), 4);
    }
}
