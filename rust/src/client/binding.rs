//! The HTTP cache binding: [`CacheBackend`] over the TVCACHE wire protocol.
//!
//! [`RemoteBinding`] speaks HTTP/1.1 (keep-alive) to a TVCACHE server — the
//! paper's `tvclient`. It implements the same [`CacheBackend`] trait as the
//! in-process [`crate::cache::ShardedCacheService`], so executors and
//! training loops are agnostic to whether the cache is embedded or remote.
//!
//! Network failures degrade to cache misses / no-ops: caching is an
//! optimization, never a correctness dependency.

use std::sync::Mutex;

use crate::cache::{
    BackendStats, CacheBackend, CacheStats, Lookup, Miss, NodeId, SnapshotCosts,
    SnapshotPolicy, SnapshotRef, ToolCall, ToolResult,
};
use crate::cache::key::trajectory_to_json;
use crate::sandbox::SandboxSnapshot;
use crate::server::{hex_decode, hex_encode};
use crate::util::http::{url_encode, HttpClient};
use crate::util::json::{self, Json};

/// Idle keep-alive connections retained per binding. One `RemoteBinding` is
/// shared by all concurrent rollouts of a process, so requests must not
/// serialize on a single connection: each request checks a connection out
/// of the pool (or dials a new one) and only the pop/push holds the lock.
/// Kept below the server's default worker count so idle pooled connections
/// cannot camp every server thread.
const MAX_IDLE_CONNECTIONS: usize = 6;

/// The server closes keep-alive connections after its 30 s idle read
/// timeout; a pooled connection older than this is presumed dead and is
/// redialed rather than reused (avoids a wasted round trip per request
/// after an idle gap).
const MAX_IDLE_AGE: std::time::Duration = std::time::Duration::from_secs(10);

/// HTTP binding to a TVCACHE server.
pub struct RemoteBinding {
    addr: std::net::SocketAddr,
    pool: Mutex<Vec<(HttpClient, std::time::Instant)>>,
}

impl RemoteBinding {
    pub fn connect(addr: std::net::SocketAddr) -> RemoteBinding {
        RemoteBinding { addr, pool: Mutex::new(Vec::new()) }
    }

    /// Run `f` with a pooled connection; I/O happens outside the pool lock.
    /// The connection returns to the pool only on success — after an error
    /// the stream may be desynchronized (a late response still in flight
    /// could be read as the answer to an unrelated later request), so it
    /// is dropped and the next request redials.
    fn with_client(
        &self,
        f: impl FnOnce(&mut HttpClient) -> std::io::Result<(u16, Vec<u8>)>,
    ) -> std::io::Result<(u16, Vec<u8>)> {
        let pooled = {
            let mut pool = self.pool.lock().unwrap();
            loop {
                match pool.pop() {
                    Some((c, last)) if last.elapsed() < MAX_IDLE_AGE => break Some(c),
                    Some(_) => continue, // presumed dead: drop, try the next
                    None => break None,
                }
            }
        };
        let mut client = pooled.unwrap_or_else(|| HttpClient::connect(self.addr));
        let out = f(&mut client);
        if out.is_ok() {
            let mut pool = self.pool.lock().unwrap();
            if pool.len() < MAX_IDLE_CONNECTIONS {
                pool.push((client, std::time::Instant::now()));
            }
        }
        out
    }

    fn post(&self, path: &str, body: String) -> Option<Json> {
        let (status, resp) = self.with_client(|c| c.post(path, body.as_bytes())).ok()?;
        if status != 200 {
            return None;
        }
        json::parse(std::str::from_utf8(&resp).ok()?).ok()
    }

    fn get(&self, path_and_query: &str) -> Option<Json> {
        let (status, resp) = self.with_client(|c| c.get(path_and_query)).ok()?;
        if status != 200 {
            return None;
        }
        json::parse(std::str::from_utf8(&resp).ok()?).ok()
    }
}

impl CacheBackend for RemoteBinding {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("trajectory", trajectory_to_json(q)),
        ])
        .to_string();
        // Safe to retry transparently: resume offers over HTTP are unpinned
        // server-side, so a replayed lookup has no pin side effect.
        let Some(v) = self.post("/prefix_match", body) else {
            // Network failure degrades to a full miss.
            return Lookup::Miss(Miss { matched_node: 0, matched_calls: 0, resume: None });
        };
        if v.get("hit").and_then(|h| h.as_bool()) == Some(true) {
            let node = v.get("node").and_then(|n| n.as_u64()).unwrap_or(0) as usize;
            let result = v
                .get("result")
                .and_then(ToolResult::from_json)
                .unwrap_or_else(|| ToolResult::new("", 0.0));
            Lookup::Hit { node, result }
        } else {
            let resume = v.get("resume").map(|r| {
                let node = r.get("node").and_then(|n| n.as_u64()).unwrap_or(0) as usize;
                let snap_id = r.get("snap_id").and_then(|s| s.as_u64()).unwrap_or(0);
                let restore = r.get("restore_cost").and_then(|c| c.as_f64()).unwrap_or(0.0);
                let replay = r.get("replay_from").and_then(|x| x.as_u64()).unwrap_or(0) as usize;
                (
                    node,
                    SnapshotRef { id: snap_id, bytes: 0, restore_cost: restore },
                    replay,
                )
            });
            Lookup::Miss(Miss {
                matched_node: v.get("matched_node").and_then(|n| n.as_u64()).unwrap_or(0)
                    as usize,
                matched_calls: v.get("matched_calls").and_then(|n| n.as_u64()).unwrap_or(0)
                    as usize,
                resume,
            })
        }
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> NodeId {
        let entries: Vec<Json> = traj
            .iter()
            .map(|(c, r)| Json::obj(vec![("call", c.to_json()), ("result", r.to_json())]))
            .collect();
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("trajectory", Json::Arr(entries)),
        ])
        .to_string();
        self.post("/put", body)
            .and_then(|v| v.get("node").and_then(|n| n.as_u64()))
            .unwrap_or(0) as usize
    }

    fn release(&self, task: &str, node: NodeId) {
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("node", Json::num(node as f64)),
        ])
        .to_string();
        self.post("/release", body);
    }

    fn should_snapshot(&self, _task: &str, costs: SnapshotCosts) -> bool {
        // Policy evaluated client-side (the server applies budget on attach).
        SnapshotPolicy::default().should_snapshot(costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("node", Json::num(node as f64)),
            ("bytes_hex", Json::str(hex_encode(&snap.bytes))),
            ("serialize_cost", Json::num(snap.serialize_cost)),
            ("restore_cost", Json::num(snap.restore_cost)),
        ])
        .to_string();
        self.post("/snapshot", body)
            .and_then(|v| v.get("id").and_then(|i| i.as_u64()))
            .unwrap_or(0)
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        let v = self.get(&format!("/snapshot?task={}&id={id}", url_encode(task)))?;
        Some(SandboxSnapshot {
            bytes: hex_decode(v.get("bytes_hex")?.as_str()?)?,
            serialize_cost: v.get("serialize_cost")?.as_f64()?,
            restore_cost: v.get("restore_cost")?.as_f64()?,
        })
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("node", Json::num(node as f64)),
            ("warm", Json::Bool(warm)),
        ])
        .to_string();
        self.post("/warm", body);
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.get(&format!("/warm?task={}&node={node}", url_encode(task)))
            .and_then(|v| v.get("warm").and_then(|w| w.as_bool()))
            .unwrap_or(false)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.get(&format!("/stats?task={}", url_encode(task)))
            .and_then(|v| CacheStats::from_json(&v))
            .unwrap_or_default()
    }

    fn service_stats(&self) -> BackendStats {
        self.get("/stats")
            .and_then(|v| BackendStats::from_json(&v))
            .unwrap_or_default()
    }

    fn persist(&self, dir: &str) -> bool {
        // `dir` names a path on the *server's* filesystem.
        let body = Json::obj(vec![("dir", Json::str(dir))]).to_string();
        self.post("/persist", body)
            .and_then(|v| v.get("ok").and_then(|o| o.as_bool()))
            .unwrap_or(false)
    }

    fn warm_start(&self, dir: &str) -> bool {
        let body = Json::obj(vec![("dir", Json::str(dir))]).to_string();
        self.post("/warm_start", body)
            .and_then(|v| v.get("ok").and_then(|o| o.as_bool()))
            .unwrap_or(false)
    }
}
