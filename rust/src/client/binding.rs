//! The HTTP cache binding: [`CacheBackend`] over the TVCACHE wire protocol.
//!
//! [`RemoteBinding`] speaks HTTP/1.1 (keep-alive) to a TVCACHE server — the
//! paper's `tvclient`. It implements the same [`CacheBackend`] trait as the
//! in-process [`crate::cache::ShardedCacheService`], so executors and
//! training loops are agnostic to whether the cache is embedded or remote.
//!
//! The hot methods (`lookup`, `insert`, `release`, and the whole cursor
//! family) speak the [`crate::wire`] binary codec; request frames are
//! encoded into a thread-local buffer reused across calls, so the
//! steady-state client path performs no request-side allocation. The cold
//! admin methods (`stats`, `persist`, `warm_start`, snapshots) stay on the
//! JSON endpoints.
//!
//! Network failures degrade to cache misses / no-ops: caching is an
//! optimization, never a correctness dependency.

use std::cell::RefCell;
use std::sync::Mutex;

use crate::cache::{
    BackendStats, CacheBackend, CacheStats, Capabilities, CursorStep, Lookup, Miss, NodeId,
    SessionBackend, SnapshotCosts, SnapshotPolicy, ToolCall, ToolResult, TurnBatch, TurnReply,
};
use crate::sandbox::SandboxSnapshot;
use crate::server::{hex_decode, hex_encode};
use crate::util::http::{url_encode, HttpClient};
use crate::util::json::{self, Json};
use crate::wire;

/// Idle keep-alive connections retained per binding. One `RemoteBinding` is
/// shared by all concurrent rollouts of a process, so requests must not
/// serialize on a single connection: each request checks a connection out
/// of the pool (or dials a new one) and only the pop/push holds the lock.
/// Kept below the server's default worker count so idle pooled connections
/// cannot camp every server thread.
const MAX_IDLE_CONNECTIONS: usize = 6;

/// The server closes keep-alive connections after its 30 s idle read
/// timeout; a pooled connection older than this is presumed dead and is
/// redialed rather than reused (avoids a wasted round trip per request
/// after an idle gap).
const MAX_IDLE_AGE: std::time::Duration = std::time::Duration::from_secs(10);

/// HTTP binding to a TVCACHE server.
pub struct RemoteBinding {
    addr: std::net::SocketAddr,
    pool: Mutex<Vec<(HttpClient, std::time::Instant)>>,
    /// Negotiated server capabilities (`/capabilities` handshake), resolved
    /// once on first session open and cached for the binding's lifetime —
    /// the per-request magic-byte guessing game this replaces is exactly
    /// what the handshake exists to avoid.
    caps: Mutex<Option<Capabilities>>,
}

impl RemoteBinding {
    pub fn connect(addr: std::net::SocketAddr) -> RemoteBinding {
        RemoteBinding { addr, pool: Mutex::new(Vec::new()), caps: Mutex::new(None) }
    }

    /// Run `f` with a pooled connection; I/O happens outside the pool lock.
    /// The connection returns to the pool only on success — after an error
    /// the stream may be desynchronized (a late response still in flight
    /// could be read as the answer to an unrelated later request), so it
    /// is dropped and the next request redials.
    fn with_client(
        &self,
        f: impl FnOnce(&mut HttpClient) -> std::io::Result<(u16, Vec<u8>)>,
    ) -> std::io::Result<(u16, Vec<u8>)> {
        let pooled = {
            let mut pool = self.pool.lock().unwrap();
            loop {
                match pool.pop() {
                    Some((c, last)) if last.elapsed() < MAX_IDLE_AGE => break Some(c),
                    Some(_) => continue, // presumed dead: drop, try the next
                    None => break None,
                }
            }
        };
        let mut client = pooled.unwrap_or_else(|| HttpClient::connect(self.addr));
        let out = f(&mut client);
        if out.is_ok() {
            let mut pool = self.pool.lock().unwrap();
            if pool.len() < MAX_IDLE_CONNECTIONS {
                pool.push((client, std::time::Instant::now()));
            }
        }
        out
    }

    fn post(&self, path: &str, body: String) -> Option<Json> {
        let (status, resp) = self.with_client(|c| c.post(path, body.as_bytes())).ok()?;
        if status != 200 {
            return None;
        }
        json::parse(std::str::from_utf8(&resp).ok()?).ok()
    }

    /// POST a binary frame built by `encode` into the thread-local reuse
    /// buffer (cleared, not reallocated, between calls); returns the raw
    /// response body on a 200. `retry` enables the one-shot transparent
    /// retry on a stale keep-alive connection — safe only for idempotent
    /// requests: a replayed `cursor_step`/`cursor_record`/`cursor_open`
    /// would apply its effect twice (double-advancing the server-side
    /// cursor or leaking an orphan one), so those pass `retry = false`
    /// and let a lost response degrade to the `Invalid`-fallback ladder.
    fn post_bin(
        &self,
        path: &str,
        retry: bool,
        encode: impl FnOnce(&mut Vec<u8>),
    ) -> Option<Vec<u8>> {
        thread_local! {
            static WIRE_BUF: RefCell<Vec<u8>> = RefCell::new(Vec::with_capacity(256));
        }
        WIRE_BUF.with(|cell| {
            let mut buf = cell.borrow_mut();
            buf.clear();
            encode(&mut buf);
            let (status, resp) = self
                .with_client(|c| {
                    if retry {
                        c.post(path, &buf)
                    } else {
                        c.post_once(path, &buf)
                    }
                })
                .ok()?;
            if status != 200 {
                return None;
            }
            Some(resp)
        })
    }

    fn get(&self, path_and_query: &str) -> Option<Json> {
        let (status, resp) = self.with_client(|c| c.get(path_and_query)).ok()?;
        if status != 200 {
            return None;
        }
        json::parse(std::str::from_utf8(&resp).ok()?).ok()
    }
}

impl CacheBackend for RemoteBinding {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        // Binary `/get` frame. Safe to retry transparently: resume offers
        // over HTTP are unpinned server-side, so a replayed lookup has no
        // pin side effect.
        self.post_bin("/get", true, |buf| wire::enc_lookup(buf, task, q))
            .as_deref()
            .and_then(wire::dec_lookup_resp)
            // Network failure degrades to a full miss.
            .unwrap_or_else(|| {
                Lookup::Miss(Miss { matched_node: 0, matched_calls: 0, resume: None })
            })
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> NodeId {
        self.post_bin("/put", true, |buf| wire::enc_insert(buf, task, traj))
            .as_deref()
            .and_then(wire::dec_u64_resp)
            .unwrap_or(0) as usize
    }

    fn release(&self, task: &str, node: NodeId) {
        let _ = self.post_bin("/release", true, |buf| wire::enc_release(buf, task, node));
    }

    fn should_snapshot(&self, _task: &str, costs: SnapshotCosts) -> bool {
        // Policy evaluated client-side (the server applies budget on attach).
        SnapshotPolicy::default().should_snapshot(costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("node", Json::num(node as f64)),
            ("bytes_hex", Json::str(hex_encode(&snap.bytes))),
            ("serialize_cost", Json::num(snap.serialize_cost)),
            ("restore_cost", Json::num(snap.restore_cost)),
        ])
        .to_string();
        self.post("/snapshot", body)
            .and_then(|v| v.get("id").and_then(|i| i.as_u64()))
            .unwrap_or(0)
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        let v = self.get(&format!("/snapshot?task={}&id={id}", url_encode(task)))?;
        Some(SandboxSnapshot {
            bytes: hex_decode(v.get("bytes_hex")?.as_str()?)?,
            serialize_cost: v.get("serialize_cost")?.as_f64()?,
            restore_cost: v.get("restore_cost")?.as_f64()?,
        })
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("node", Json::num(node as f64)),
            ("warm", Json::Bool(warm)),
        ])
        .to_string();
        self.post("/warm", body);
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.get(&format!("/warm?task={}&node={node}", url_encode(task)))
            .and_then(|v| v.get("warm").and_then(|w| w.as_bool()))
            .unwrap_or(false)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.get(&format!("/stats?task={}", url_encode(task)))
            .and_then(|v| CacheStats::from_json(&v))
            .unwrap_or_default()
    }

    fn service_stats(&self) -> BackendStats {
        self.get("/stats")
            .and_then(|v| BackendStats::from_json(&v))
            .unwrap_or_default()
    }

    fn persist(&self, dir: &str) -> bool {
        // `dir` names a path on the *server's* filesystem.
        let body = Json::obj(vec![("dir", Json::str(dir))]).to_string();
        self.post("/persist", body)
            .and_then(|v| v.get("ok").and_then(|o| o.as_bool()))
            .unwrap_or(false)
    }

    fn warm_start(&self, dir: &str) -> bool {
        let body = Json::obj(vec![("dir", Json::str(dir))]).to_string();
        self.post("/warm_start", body)
            .and_then(|v| v.get("ok").and_then(|o| o.as_bool()))
            .unwrap_or(false)
    }
}

impl SessionBackend for RemoteBinding {
    /// One `/capabilities` round trip, once per binding (not per session,
    /// not per request). A server that 404s the handshake — or a network
    /// hiccup — negotiates down to [`Capabilities::LEGACY`]: the magic-byte
    /// sniffed binary + cursor protocol every pre-v2 server speaks, with
    /// turn batching off. The decision is cached so a flaky handshake can
    /// never flap the protocol mid-run.
    fn capabilities(&self) -> Capabilities {
        if let Some(c) = *self.caps.lock().unwrap() {
            return c;
        }
        let negotiated = self
            .post_bin("/capabilities", true, |buf| {
                wire::enc_hello(buf, Capabilities::PROTO_V2)
            })
            .as_deref()
            .and_then(wire::dec_caps_resp)
            .map(|(_proto, caps)| caps)
            .unwrap_or(Capabilities::LEGACY);
        *self.caps.lock().unwrap() = Some(negotiated);
        negotiated
    }

    fn cursor_open(&self, task: &str) -> u64 {
        self.post_bin("/cursor_open", false, |buf| wire::enc_cursor_open(buf, task))
            .as_deref()
            .and_then(wire::dec_u64_resp)
            .unwrap_or(0)
    }

    fn cursor_step(&self, task: &str, cursor: u64, call: &ToolCall) -> CursorStep {
        // The O(1) hot frame: only the delta call crosses the wire. A
        // transport failure reports `Invalid`, which the executor treats
        // as "fall back to a full-prefix lookup" — the same degradation
        // ladder as a server-side eviction.
        self.post_bin("/cursor_step", false, |buf| {
            wire::enc_cursor_step(buf, task, cursor, call)
        })
        .as_deref()
        .and_then(wire::dec_step_resp)
        .unwrap_or(CursorStep::Invalid)
    }

    fn cursor_record(
        &self,
        task: &str,
        cursor: u64,
        call: &ToolCall,
        result: &ToolResult,
    ) -> NodeId {
        self.post_bin("/cursor_record", false, |buf| {
            wire::enc_cursor_record(buf, task, cursor, call, result)
        })
        .as_deref()
        .and_then(wire::dec_u64_resp)
        .unwrap_or(0) as usize
    }

    fn cursor_seek(&self, task: &str, cursor: u64, node: NodeId, steps: usize) -> bool {
        self.post_bin("/cursor_seek", true, |buf| {
            wire::enc_cursor_seek(buf, task, cursor, node, steps)
        })
        .as_deref()
        .and_then(wire::dec_bool_resp)
        .unwrap_or(false)
    }

    fn cursor_close(&self, task: &str, cursor: u64) {
        let _ =
            self.post_bin("/cursor_close", true, |buf| wire::enc_cursor_close(buf, task, cursor));
    }

    /// Session-owned pin release. Not retried: a lost response leaves the
    /// pin registered on the server-side session entry, which releases it
    /// at close/sweep — bounded by the session lifetime instead of leaked
    /// forever (the failure mode that forced the legacy wire protocol to
    /// unpin offers before replying).
    fn session_release(&self, task: &str, cursor: u64, node: NodeId) {
        let _ = self.post_bin("/session_release", false, |buf| {
            wire::enc_session_release(buf, task, cursor, node)
        });
    }

    /// One reasoning turn, one round trip (`/session_turn`). Never retried
    /// transparently — a replayed step/record would double-apply; a lost
    /// response degrades through [`TurnReply::refused`] into the same
    /// `Invalid`-fallback ladder as a server-side eviction.
    fn session_turn(&self, task: &str, cursor: u64, batch: &TurnBatch) -> TurnReply {
        self.post_bin("/session_turn", false, |buf| {
            wire::enc_turn(buf, task, cursor, batch)
        })
        .as_deref()
        .and_then(wire::dec_turn_resp)
        .unwrap_or_else(|| TurnReply::refused(batch))
    }
}
