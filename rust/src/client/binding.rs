//! Cache bindings: how the `ToolCallExecutor` talks to TVCACHE.
//!
//! `LocalBinding` embeds the cache in-process (simulation experiments, where
//! cache latency is *charged* rather than measured). `RemoteBinding` speaks
//! the HTTP wire protocol to a real TVCACHE server (Figure 8 benchmarks,
//! integration tests).

use std::sync::{Arc, Mutex};

use crate::cache::{Lookup, SnapshotCosts, SnapshotRef, TaskCache, ToolCall, ToolResult};
use crate::cache::key::trajectory_to_json;
use crate::sandbox::SandboxSnapshot;
use crate::server::{hex_decode, hex_encode, SnapshotStore};
use crate::util::http::HttpClient;
use crate::util::json::{self, Json};

/// The executor's view of the cache.
pub trait CacheBinding: Send {
    fn lookup(&self, q: &[ToolCall]) -> Lookup;
    fn record(&self, traj: &[(ToolCall, ToolResult)]) -> usize;
    fn release(&self, node: usize);
    fn should_snapshot(&self, costs: SnapshotCosts) -> bool;
    /// Store `snap` for `node`; returns the snapshot id.
    fn attach_snapshot(&self, node: usize, snap: SandboxSnapshot) -> u64;
    fn fetch_snapshot(&self, id: u64) -> Option<SandboxSnapshot>;
    fn set_warm_fork(&self, node: usize, warm: bool);
    fn has_warm_fork(&self, node: usize) -> bool;
}

/// In-process binding: `TaskCache` + `SnapshotStore`.
pub struct LocalBinding {
    pub cache: Arc<TaskCache>,
    pub snapshots: Arc<SnapshotStore>,
}

impl LocalBinding {
    pub fn new(cache: Arc<TaskCache>) -> LocalBinding {
        LocalBinding { cache, snapshots: Arc::new(SnapshotStore::default()) }
    }

    pub fn shared(cache: Arc<TaskCache>, snapshots: Arc<SnapshotStore>) -> LocalBinding {
        LocalBinding { cache, snapshots }
    }
}

impl CacheBinding for LocalBinding {
    fn lookup(&self, q: &[ToolCall]) -> Lookup {
        self.cache.lookup(q)
    }

    fn record(&self, traj: &[(ToolCall, ToolResult)]) -> usize {
        self.cache.record_trajectory(traj)
    }

    fn release(&self, node: usize) {
        self.cache.release(node);
    }

    fn should_snapshot(&self, costs: SnapshotCosts) -> bool {
        self.cache.should_snapshot(costs)
    }

    fn attach_snapshot(&self, node: usize, snap: SandboxSnapshot) -> u64 {
        let size = snap.size();
        let restore_cost = snap.restore_cost;
        let id = self.snapshots.insert(snap);
        let freed = self
            .cache
            .attach_snapshot(node, SnapshotRef { id, bytes: size, restore_cost });
        for f in freed {
            self.snapshots.remove(f.id);
        }
        id
    }

    fn fetch_snapshot(&self, id: u64) -> Option<SandboxSnapshot> {
        self.snapshots.get(id)
    }

    fn set_warm_fork(&self, node: usize, warm: bool) {
        self.cache.set_warm_fork(node, warm);
    }

    fn has_warm_fork(&self, node: usize) -> bool {
        self.cache.has_warm_fork(node)
    }
}

/// HTTP binding to a TVCACHE server (the `tvclient` analogue).
pub struct RemoteBinding {
    task: String,
    client: Mutex<HttpClient>,
}

impl RemoteBinding {
    pub fn connect(addr: std::net::SocketAddr, task: impl Into<String>) -> RemoteBinding {
        RemoteBinding { task: task.into(), client: Mutex::new(HttpClient::connect(addr)) }
    }

    fn post(&self, path: &str, body: String) -> Option<Json> {
        let mut c = self.client.lock().unwrap();
        let (status, resp) = c.post(path, body.as_bytes()).ok()?;
        if status != 200 {
            return None;
        }
        json::parse(std::str::from_utf8(&resp).ok()?).ok()
    }
}

impl CacheBinding for RemoteBinding {
    fn lookup(&self, q: &[ToolCall]) -> Lookup {
        let body = Json::obj(vec![
            ("task", Json::str(self.task.clone())),
            ("trajectory", trajectory_to_json(q)),
        ])
        .to_string();
        let Some(v) = self.post("/prefix_match", body) else {
            // Network failure degrades to a full miss — caching is an
            // optimization, never a correctness dependency.
            return Lookup::Miss(crate::cache::Miss {
                matched_node: 0,
                matched_calls: 0,
                resume: None,
            });
        };
        if v.get("hit").and_then(|h| h.as_bool()) == Some(true) {
            let node = v.get("node").and_then(|n| n.as_u64()).unwrap_or(0) as usize;
            let result = v
                .get("result")
                .and_then(ToolResult::from_json)
                .unwrap_or_else(|| ToolResult::new("", 0.0));
            Lookup::Hit { node, result }
        } else {
            let resume = v.get("resume").map(|r| {
                let node = r.get("node").and_then(|n| n.as_u64()).unwrap_or(0) as usize;
                let snap_id = r.get("snap_id").and_then(|s| s.as_u64()).unwrap_or(0);
                let restore = r.get("restore_cost").and_then(|c| c.as_f64()).unwrap_or(0.0);
                let replay = r.get("replay_from").and_then(|x| x.as_u64()).unwrap_or(0) as usize;
                (
                    node,
                    SnapshotRef { id: snap_id, bytes: 0, restore_cost: restore },
                    replay,
                )
            });
            Lookup::Miss(crate::cache::Miss {
                matched_node: v.get("matched_node").and_then(|n| n.as_u64()).unwrap_or(0)
                    as usize,
                matched_calls: v.get("matched_calls").and_then(|n| n.as_u64()).unwrap_or(0)
                    as usize,
                resume,
            })
        }
    }

    fn record(&self, traj: &[(ToolCall, ToolResult)]) -> usize {
        let entries: Vec<Json> = traj
            .iter()
            .map(|(c, r)| Json::obj(vec![("call", c.to_json()), ("result", r.to_json())]))
            .collect();
        let body = Json::obj(vec![
            ("task", Json::str(self.task.clone())),
            ("trajectory", Json::Arr(entries)),
        ])
        .to_string();
        self.post("/put", body)
            .and_then(|v| v.get("node").and_then(|n| n.as_u64()))
            .unwrap_or(0) as usize
    }

    fn release(&self, node: usize) {
        let body = Json::obj(vec![
            ("task", Json::str(self.task.clone())),
            ("node", Json::num(node as f64)),
        ])
        .to_string();
        self.post("/release", body);
    }

    fn should_snapshot(&self, costs: SnapshotCosts) -> bool {
        // Policy evaluated client-side (the server applies budget on attach).
        crate::cache::SnapshotPolicy::default().should_snapshot(costs)
    }

    fn attach_snapshot(&self, node: usize, snap: SandboxSnapshot) -> u64 {
        let body = Json::obj(vec![
            ("task", Json::str(self.task.clone())),
            ("node", Json::num(node as f64)),
            ("bytes_hex", Json::str(hex_encode(&snap.bytes))),
            ("serialize_cost", Json::num(snap.serialize_cost)),
            ("restore_cost", Json::num(snap.restore_cost)),
        ])
        .to_string();
        self.post("/snapshot", body)
            .and_then(|v| v.get("id").and_then(|i| i.as_u64()))
            .unwrap_or(0)
    }

    fn fetch_snapshot(&self, id: u64) -> Option<SandboxSnapshot> {
        let mut c = self.client.lock().unwrap();
        let (status, resp) = c.get(&format!("/snapshot?id={id}")).ok()?;
        if status != 200 {
            return None;
        }
        let v = json::parse(std::str::from_utf8(&resp).ok()?).ok()?;
        Some(SandboxSnapshot {
            bytes: hex_decode(v.get("bytes_hex")?.as_str()?)?,
            serialize_cost: v.get("serialize_cost")?.as_f64()?,
            restore_cost: v.get("restore_cost")?.as_f64()?,
        })
    }

    fn set_warm_fork(&self, node: usize, warm: bool) {
        let body = Json::obj(vec![
            ("task", Json::str(self.task.clone())),
            ("node", Json::num(node as f64)),
            ("warm", Json::Bool(warm)),
        ])
        .to_string();
        self.post("/warm", body);
    }

    fn has_warm_fork(&self, _node: usize) -> bool {
        false // remote warm-state is advisory; executor re-checks via resume
    }
}
