//! The HTTP cache binding: [`CacheBackend`] over the TVCACHE wire protocol.
//!
//! [`RemoteBinding`] speaks HTTP/1.1 (keep-alive) to a TVCACHE server — the
//! paper's `tvclient`. It implements the same [`CacheBackend`] trait as the
//! in-process [`crate::cache::ShardedCacheService`], so executors and
//! training loops are agnostic to whether the cache is embedded or remote.
//!
//! The hot methods (`lookup`, `insert`, `release`, and the whole cursor
//! family) speak the [`crate::wire`] binary codec; request frames are
//! encoded into a thread-local buffer reused across calls, so the
//! steady-state client path performs no request-side allocation. The cold
//! admin methods (`stats`, `persist`, `warm_start`, snapshots) stay on the
//! JSON endpoints.
//!
//! Network failures degrade to cache misses / no-ops: caching is an
//! optimization, never a correctness dependency. Three mechanisms bound
//! the cost of a sick server (all tunable via [`BindingConfig`]):
//!
//! * **Deadlines** — every dial uses a connect timeout and every response
//!   read a socket read deadline, so a hung or blackholed server costs at
//!   most one deadline per attempt, never an indefinite block.
//! * **Bounded retries** — idempotent requests retry with exponential
//!   backoff + jitter; non-idempotent ones (cursor steps/records, turn
//!   frames) never retry and degrade through their documented ladders.
//! * **A circuit breaker** — after `breaker_threshold` consecutive failed
//!   requests the binding stops sending entirely ([`CacheBackend::degraded`]
//!   reports `true`, executors bypass the cache); after
//!   `breaker_cooldown` a single half-open probe tests recovery and one
//!   success closes the breaker again.
//!
//! With [`BindingConfig::endpoints`] listing one or more warm followers, an
//! opening breaker additionally attempts a **failover**: it POSTs
//! `/promote` to each other endpoint and switches to the first whose
//! returned fencing epoch is at least the highest epoch this binding has
//! ever seen — so a revived stale primary can never win the promotion.
//! After the switch, the binding's generation counter bumps; rollout
//! sessions observe it and re-seed their cursors on the new server (cursor
//! tables are per-server state). Every sealed binary reply is epoch-checked
//! too: frames stamped below the high-water epoch are rejected
//! (`epoch_rejects`), which is what fences a stale primary that comes back
//! mid-conversation. The cache is bypassed only while *no* endpoint is
//! healthy.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::cache::{
    BackendStats, CacheBackend, CacheStats, Capabilities, CursorStep, Lookup, Miss, NodeId,
    SessionBackend, SnapshotCosts, SnapshotPolicy, ToolCall, ToolResult, TurnBatch, TurnReply,
};
use crate::sandbox::SandboxSnapshot;
use crate::server::{hex_decode, hex_encode};
use crate::util::http::{url_encode, HttpClient};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::wire;

/// Idle keep-alive connections retained per binding. One `RemoteBinding` is
/// shared by all concurrent rollouts of a process, so requests must not
/// serialize on a single connection: each request checks a connection out
/// of the pool (or dials a new one) and only the pop/push holds the lock.
/// Kept below the server's default worker count so idle pooled connections
/// cannot camp every server thread.
const MAX_IDLE_CONNECTIONS: usize = 6;

/// A pooled connection idle longer than this is presumed dead and is
/// redialed rather than reused (avoids a wasted round trip per request
/// after an idle gap). Deliberately far below the server's 30 s idle read
/// timeout, so the binding never races the server's close of a connection
/// it is about to reuse.
const MAX_IDLE_AGE: Duration = Duration::from_secs(10);

/// Circuit-breaker state encoding (an `AtomicU8` on the binding).
const BREAKER_CLOSED: u8 = 0;
const BREAKER_OPEN: u8 = 1;
const BREAKER_HALF_OPEN: u8 = 2;

/// Transport robustness knobs for a [`RemoteBinding`].
#[derive(Debug, Clone)]
pub struct BindingConfig {
    /// Per-attempt dial deadline.
    pub connect_timeout: Duration,
    /// Per-response socket read deadline.
    pub read_timeout: Duration,
    /// Extra attempts after the first, for idempotent requests only.
    pub retries: u32,
    /// Backoff before retry *n* is `backoff_base × 2^(n−1)` (then jitter).
    pub backoff_base: Duration,
    /// Ceiling on the exponential backoff (pre-jitter).
    pub backoff_max: Duration,
    /// Consecutive failed requests that trip the breaker open.
    pub breaker_threshold: u32,
    /// How long an open breaker waits before a half-open recovery probe.
    pub breaker_cooldown: Duration,
    /// Seed for backoff jitter (deterministic tests).
    pub seed: u64,
    /// Minimum gap between `/promote` probes to the *same* candidate
    /// endpoint. A flapping group re-opens its breaker every cooldown, and
    /// without this gate each re-open re-probes every candidate — a
    /// follower that just rejected a promotion (or answered with a fenced
    /// epoch) would be hammered with promote requests it will keep
    /// refusing. Candidates inside their cooldown are skipped, not waited
    /// for; `Duration::ZERO` disables the gate (tests).
    pub probe_cooldown: Duration,
    /// Additional endpoints (warm followers) beyond the primary address the
    /// binding was connected to. When the breaker opens, the binding tries
    /// to promote-and-fail-over to one of these before giving up on the
    /// cache entirely.
    pub endpoints: Vec<std::net::SocketAddr>,
}

impl Default for BindingConfig {
    fn default() -> BindingConfig {
        BindingConfig {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(30),
            retries: 2,
            backoff_base: Duration::from_millis(25),
            backoff_max: Duration::from_millis(400),
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_secs(2),
            seed: 0x7C1E,
            probe_cooldown: Duration::from_secs(1),
            endpoints: Vec::new(),
        }
    }
}

/// Outcome of a [`RemoteBinding::drain`] call (`POST /drain`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DrainReport {
    /// The follower acknowledged the whole op-log before the deadline
    /// (vacuously `true` on a server with no follower to wait for).
    pub caught_up: bool,
    /// The primary's final op sequence at drain time.
    pub final_seq: u64,
    /// `Some(ok)` when a persist dir was requested in the drain.
    pub persisted: Option<bool>,
}

/// HTTP binding to a TVCACHE server.
pub struct RemoteBinding {
    /// All known endpoints: the connect address first, then
    /// [`BindingConfig::endpoints`]. `active` indexes into this.
    endpoints: Vec<std::net::SocketAddr>,
    active: AtomicUsize,
    cfg: BindingConfig,
    /// Idle keep-alive connections, each tagged with the endpoint index it
    /// was dialed against — a failover must never reuse a connection to
    /// the old primary.
    pool: Mutex<Vec<(HttpClient, Instant, usize)>>,
    /// Negotiated server capabilities (`/capabilities` handshake), resolved
    /// once on first session open and cached for the binding's lifetime —
    /// the per-request magic-byte guessing game this replaces is exactly
    /// what the handshake exists to avoid. Left unset after a *transport*
    /// failure (the next open re-probes); only a definitive server answer
    /// is cached.
    caps: Mutex<Option<Capabilities>>,
    /// Circuit breaker: CLOSED (traffic flows) / OPEN (fast-fail
    /// everything) / HALF_OPEN (exactly one probe in flight).
    breaker: AtomicU8,
    consecutive_failures: AtomicU32,
    /// When the breaker last opened (gates the half-open cooldown).
    opened_at: Mutex<Instant>,
    /// Jitter source for retry backoff.
    jitter: Mutex<Rng>,
    /// Per-endpoint timestamp of the last `/promote` probe (indexed like
    /// `endpoints`); gates re-probing a candidate that just refused.
    probe_stamps: Mutex<Vec<Option<Instant>>>,
    /// Highest fencing epoch observed in any sealed reply or promotion
    /// answer. Replies (and promotion offers) below it are rejected.
    max_epoch: AtomicU64,
    /// Bumped on every endpoint switch; sessions watch it (via
    /// `backend_generation`) and re-seed their cursors on the new server.
    generation: AtomicU64,
    // ---- client-side degradation counters (merged into service_stats) ----
    retries_counter: AtomicU64,
    breaker_opens: AtomicU64,
    breaker_half_opens: AtomicU64,
    breaker_closes: AtomicU64,
    failovers_counter: AtomicU64,
    epoch_rejects_counter: AtomicU64,
}

impl RemoteBinding {
    pub fn connect(addr: std::net::SocketAddr) -> RemoteBinding {
        Self::connect_with(addr, BindingConfig::default())
    }

    /// Connect with explicit deadline/retry/breaker configuration.
    pub fn connect_with(addr: std::net::SocketAddr, cfg: BindingConfig) -> RemoteBinding {
        let jitter = Rng::new(cfg.seed ^ 0xB1D1_76AD);
        let mut endpoints = vec![addr];
        endpoints.extend(cfg.endpoints.iter().copied().filter(|e| *e != addr));
        let probe_stamps = Mutex::new(vec![None; endpoints.len()]);
        RemoteBinding {
            endpoints,
            active: AtomicUsize::new(0),
            cfg,
            pool: Mutex::new(Vec::new()),
            caps: Mutex::new(None),
            breaker: AtomicU8::new(BREAKER_CLOSED),
            consecutive_failures: AtomicU32::new(0),
            opened_at: Mutex::new(Instant::now()),
            jitter: Mutex::new(jitter),
            probe_stamps,
            max_epoch: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            retries_counter: AtomicU64::new(0),
            breaker_opens: AtomicU64::new(0),
            breaker_half_opens: AtomicU64::new(0),
            breaker_closes: AtomicU64::new(0),
            failovers_counter: AtomicU64::new(0),
            epoch_rejects_counter: AtomicU64::new(0),
        }
    }

    /// The endpoint requests currently go to.
    pub fn active_endpoint(&self) -> std::net::SocketAddr {
        self.endpoints[self.active.load(Ordering::Acquire)]
    }

    /// Completed endpoint failovers.
    pub fn failovers(&self) -> u64 {
        self.failovers_counter.load(Ordering::Relaxed)
    }

    /// Replies or promotion offers rejected by the epoch fence.
    pub fn epoch_rejects(&self) -> u64 {
        self.epoch_rejects_counter.load(Ordering::Relaxed)
    }

    /// Highest fencing epoch this binding has observed.
    pub fn max_epoch_seen(&self) -> u64 {
        self.max_epoch.load(Ordering::Acquire)
    }

    /// Current breaker state, for tests and debug surfaces:
    /// `"closed" | "open" | "half-open"`.
    pub fn breaker_state(&self) -> &'static str {
        match self.breaker.load(Ordering::Acquire) {
            BREAKER_OPEN => "open",
            BREAKER_HALF_OPEN => "half-open",
            _ => "closed",
        }
    }

    /// Run `f` with a pooled connection; I/O happens outside the pool lock.
    /// The connection returns to the pool only on success — after an error
    /// the stream may be desynchronized (a late response still in flight
    /// could be read as the answer to an unrelated later request), so it
    /// is dropped and the next request redials.
    fn with_client(
        &self,
        f: impl FnOnce(&mut HttpClient) -> std::io::Result<(u16, Vec<u8>)>,
    ) -> std::io::Result<(u16, Vec<u8>)> {
        let active = self.active.load(Ordering::Acquire);
        let pooled = {
            let mut pool = self.pool.lock().unwrap();
            loop {
                match pool.pop() {
                    // A connection to another endpoint (pre-failover
                    // leftover) is dropped like a dead one.
                    Some((c, last, idx)) if idx == active && last.elapsed() < MAX_IDLE_AGE => {
                        break Some(c)
                    }
                    Some(_) => continue, // presumed dead: drop, try the next
                    None => break None,
                }
            }
        };
        let mut client = pooled.unwrap_or_else(|| {
            HttpClient::with_deadlines(
                self.endpoints[active],
                self.cfg.connect_timeout,
                self.cfg.read_timeout,
            )
        });
        let out = f(&mut client);
        if out.is_ok() {
            let mut pool = self.pool.lock().unwrap();
            if pool.len() < MAX_IDLE_CONNECTIONS
                && self.active.load(Ordering::Acquire) == active
            {
                pool.push((client, Instant::now(), active));
            }
        }
        out
    }

    /// One logical request through the breaker + bounded-retry policy.
    ///
    /// *Any* HTTP response — 200, 404, 500 — counts as transport success
    /// (the server is alive and answering); only an `io::Error` after all
    /// attempts counts against the breaker. `retry` must be `true` only
    /// for idempotent requests: every attempt re-sends the frame, so a
    /// replayed non-idempotent op (cursor step/record, turn frame) would
    /// double-apply.
    fn transport(
        &self,
        retry: bool,
        mut send: impl FnMut(&mut HttpClient) -> std::io::Result<(u16, Vec<u8>)>,
    ) -> std::io::Result<(u16, Vec<u8>)> {
        if !self.breaker_allows() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionRefused,
                "circuit breaker open: cache traffic bypassed",
            ));
        }
        let attempts = if retry { 1 + self.cfg.retries } else { 1 };
        let mut last_err = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.retries_counter.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.backoff(attempt));
            }
            match self.with_client(&mut send) {
                Ok(resp) => {
                    self.note_success();
                    return Ok(resp);
                }
                Err(e) => last_err = Some(e),
            }
        }
        self.note_transport_failure();
        Err(last_err.unwrap_or_else(|| std::io::Error::other("transport failed")))
    }

    /// Backoff before retry `attempt` (≥ 1): exponential from
    /// `backoff_base`, capped at `backoff_max`, jittered to 50–100 % so
    /// concurrent rollout threads don't retry in lockstep.
    fn backoff(&self, attempt: u32) -> Duration {
        let exp = self
            .cfg
            .backoff_base
            .saturating_mul(1u32 << (attempt - 1).min(16));
        let capped = exp.min(self.cfg.backoff_max);
        let jitter = 0.5 + 0.5 * self.jitter.lock().unwrap().f64();
        capped.mul_f64(jitter)
    }

    /// May a request go out right now? In HALF_OPEN exactly one caller —
    /// the one whose compare-exchange moved OPEN → HALF_OPEN — gets
    /// through as the recovery probe; everyone else fast-fails.
    fn breaker_allows(&self) -> bool {
        match self.breaker.load(Ordering::Acquire) {
            BREAKER_CLOSED => true,
            BREAKER_HALF_OPEN => false, // a probe is already in flight
            _ => {
                self.opened_at.lock().unwrap().elapsed() >= self.cfg.breaker_cooldown
                    && self.try_half_open()
            }
        }
    }

    fn try_half_open(&self) -> bool {
        let won = self
            .breaker
            .compare_exchange(
                BREAKER_OPEN,
                BREAKER_HALF_OPEN,
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok();
        if won {
            self.breaker_half_opens.fetch_add(1, Ordering::Relaxed);
        }
        won
    }

    fn note_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        if self.breaker.swap(BREAKER_CLOSED, Ordering::AcqRel) != BREAKER_CLOSED {
            self.breaker_closes.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn note_transport_failure(&self) {
        if self.breaker.load(Ordering::Acquire) == BREAKER_HALF_OPEN {
            // Failed recovery probe: reopen and restart the cooldown clock.
            *self.opened_at.lock().unwrap() = Instant::now();
            if self.breaker.swap(BREAKER_OPEN, Ordering::AcqRel) == BREAKER_HALF_OPEN {
                self.breaker_opens.fetch_add(1, Ordering::Relaxed);
                // The active endpoint is still sick after a cooldown:
                // another chance for a warm follower to take over.
                self.try_failover();
            }
            return;
        }
        let fails = self.consecutive_failures.fetch_add(1, Ordering::AcqRel) + 1;
        if fails >= self.cfg.breaker_threshold {
            // Stamp the clock before flipping the state so no reader of
            // OPEN can observe a stale cooldown start.
            *self.opened_at.lock().unwrap() = Instant::now();
            if self
                .breaker
                .compare_exchange(
                    BREAKER_CLOSED,
                    BREAKER_OPEN,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                self.breaker_opens.fetch_add(1, Ordering::Relaxed);
                self.try_failover();
            }
        }
    }

    /// The breaker just opened against the active endpoint: try to promote
    /// one of the other endpoints and switch to it. Accepts a candidate
    /// only when its `/promote` answer carries an epoch at least the
    /// highest this binding has ever seen — a revived stale primary
    /// (which reports its old epoch without bumping) is rejected and
    /// counted in `epoch_rejects`. On success the breaker closes, the
    /// connection pool and cached capabilities reset, and the generation
    /// counter bumps so sessions re-seed on the new server. When every
    /// candidate fails, the breaker stays open: only then is the cache
    /// actually bypassed.
    ///
    /// Each candidate is probed at most once per
    /// [`BindingConfig::probe_cooldown`]: a flapping server re-opens the
    /// breaker every `breaker_cooldown`, and without the gate each
    /// re-open would re-spam `/promote` at candidates that just refused.
    fn try_failover(&self) {
        if self.endpoints.len() < 2 {
            return;
        }
        let active = self.active.load(Ordering::Acquire);
        for off in 1..self.endpoints.len() {
            let idx = (active + off) % self.endpoints.len();
            if !self.probe_allowed(idx) {
                continue;
            }
            let mut probe = HttpClient::with_deadlines(
                self.endpoints[idx],
                self.cfg.connect_timeout,
                self.cfg.read_timeout,
            );
            let Ok((200, body)) = probe.post("/promote", b"") else {
                continue;
            };
            let epoch = std::str::from_utf8(&body)
                .ok()
                .and_then(|s| json::parse(s).ok())
                .and_then(|v| v.get("epoch").and_then(|e| e.as_u64()));
            let Some(epoch) = epoch else { continue };
            let prev = self.max_epoch.fetch_max(epoch, Ordering::AcqRel);
            if epoch < prev {
                self.epoch_rejects_counter.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            self.active.store(idx, Ordering::Release);
            self.pool.lock().unwrap().clear();
            // The new server gets a fresh handshake on the next open.
            *self.caps.lock().unwrap() = None;
            self.generation.fetch_add(1, Ordering::AcqRel);
            self.failovers_counter.fetch_add(1, Ordering::Relaxed);
            self.note_success();
            return;
        }
    }

    /// May candidate `idx` be promote-probed right now? Stamps the probe
    /// time on `true`, so concurrent breaker-open paths racing through
    /// here still send at most one probe per candidate per cooldown.
    fn probe_allowed(&self, idx: usize) -> bool {
        let mut stamps = self.probe_stamps.lock().unwrap();
        if let Some(at) = stamps[idx] {
            if at.elapsed() < self.cfg.probe_cooldown {
                return false;
            }
        }
        stamps[idx] = Some(Instant::now());
        true
    }

    fn post(&self, path: &str, body: String) -> Option<Json> {
        let (status, resp) = self
            .transport(true, |c| c.post_once(path, body.as_bytes()))
            .ok()?;
        if status != 200 {
            return None;
        }
        json::parse(std::str::from_utf8(&resp).ok()?).ok()
    }

    /// POST a binary frame built by `encode` into the thread-local reuse
    /// buffer (cleared, not reallocated, between calls); returns the
    /// status and raw response body, or the transport error after the
    /// retry policy is exhausted. `retry` routes through the bounded
    /// idempotent-retry policy — safe only for requests whose replay has
    /// no side effect: a replayed `cursor_step`/`cursor_record`/
    /// `cursor_open` would apply its effect twice (double-advancing the
    /// server-side cursor or leaking an orphan one), so those pass
    /// `retry = false` and let a lost response degrade to the
    /// `Invalid`-fallback ladder.
    fn post_bin_status(
        &self,
        path: &str,
        retry: bool,
        encode: impl FnOnce(&mut Vec<u8>),
    ) -> std::io::Result<(u16, Vec<u8>)> {
        thread_local! {
            static WIRE_BUF: RefCell<Vec<u8>> = RefCell::new(Vec::with_capacity(256));
        }
        let out = WIRE_BUF.with(|cell| {
            let mut buf = cell.borrow_mut();
            buf.clear();
            encode(&mut buf);
            self.transport(retry, |c| c.post_once(path, &buf))
        });
        // Epoch fence on every sealed binary reply: a frame stamped below
        // the highest epoch this binding has seen can only come from a
        // stale primary answering after a failover — its state diverged
        // from the promoted line, so the answer must not be trusted.
        if let Ok((200, body)) = &out {
            if let Some(epoch) = wire::resp_epoch(body) {
                let prev = self.max_epoch.fetch_max(epoch, Ordering::AcqRel);
                if epoch < prev {
                    self.epoch_rejects_counter.fetch_add(1, Ordering::Relaxed);
                    self.note_transport_failure();
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "reply fenced: stale epoch",
                    ));
                }
            }
        }
        out
    }

    /// [`Self::post_bin_status`] collapsed to `Some(body)` on a 200.
    fn post_bin(
        &self,
        path: &str,
        retry: bool,
        encode: impl FnOnce(&mut Vec<u8>),
    ) -> Option<Vec<u8>> {
        match self.post_bin_status(path, retry, encode) {
            Ok((200, body)) => Some(body),
            _ => None,
        }
    }

    fn get(&self, path_and_query: &str) -> Option<Json> {
        let (status, resp) = self.transport(true, |c| c.get(path_and_query)).ok()?;
        if status != 200 {
            return None;
        }
        json::parse(std::str::from_utf8(&resp).ok()?).ok()
    }

    /// Gracefully drain the active server (`POST /drain`): it stops
    /// admitting new sessions, waits (bounded) for its follower to catch
    /// up, and — when `dir` is given — persists to that *server-local*
    /// path. `None` on transport failure. Safe to retry: draining is
    /// sticky and a re-run persist overwrites the same checkpoint.
    pub fn drain(&self, dir: Option<&str>) -> Option<DrainReport> {
        let body = match dir {
            Some(d) => Json::obj(vec![("dir", Json::str(d))]).to_string(),
            None => String::new(),
        };
        let v = self.post("/drain", body)?;
        Some(DrainReport {
            caught_up: v.get("caught_up")?.as_bool()?,
            final_seq: v.get("final_seq")?.as_u64()?,
            persisted: v.get("persisted").and_then(|p| p.as_bool()),
        })
    }
}

impl CacheBackend for RemoteBinding {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        // Binary `/get` frame. Safe to retry transparently: resume offers
        // over HTTP are unpinned server-side, so a replayed lookup has no
        // pin side effect.
        self.post_bin("/get", true, |buf| wire::enc_lookup(buf, task, q))
            .as_deref()
            .and_then(wire::dec_lookup_resp)
            // Network failure degrades to a full miss.
            .unwrap_or_else(|| {
                Lookup::Miss(Miss { matched_node: 0, matched_calls: 0, resume: None })
            })
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> Option<NodeId> {
        // `None` (transport failure) is distinct from `Some(0)` (the
        // server answered: final node is ROOT) — a failed insert must
        // never be released, pinned, or snapshot-attached as ROOT.
        self.post_bin("/put", true, |buf| wire::enc_insert(buf, task, traj))
            .as_deref()
            .and_then(wire::dec_u64_resp)
            .map(|n| n as usize)
    }

    fn release(&self, task: &str, node: NodeId) {
        let _ = self.post_bin("/release", true, |buf| wire::enc_release(buf, task, node));
    }

    fn should_snapshot(&self, _task: &str, costs: SnapshotCosts) -> bool {
        // Policy evaluated client-side (the server applies budget on attach).
        SnapshotPolicy::default().should_snapshot(costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("node", Json::num(node as f64)),
            ("bytes_hex", Json::str(hex_encode(&snap.bytes))),
            ("serialize_cost", Json::num(snap.serialize_cost)),
            ("restore_cost", Json::num(snap.restore_cost)),
        ])
        .to_string();
        self.post("/snapshot", body)
            .and_then(|v| v.get("id").and_then(|i| i.as_u64()))
            .unwrap_or(0)
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        let v = self.get(&format!("/snapshot?task={}&id={id}", url_encode(task)))?;
        Some(SandboxSnapshot {
            bytes: hex_decode(v.get("bytes_hex")?.as_str()?)?,
            serialize_cost: v.get("serialize_cost")?.as_f64()?,
            restore_cost: v.get("restore_cost")?.as_f64()?,
        })
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        let body = Json::obj(vec![
            ("task", Json::str(task)),
            ("node", Json::num(node as f64)),
            ("warm", Json::Bool(warm)),
        ])
        .to_string();
        self.post("/warm", body);
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.get(&format!("/warm?task={}&node={node}", url_encode(task)))
            .and_then(|v| v.get("warm").and_then(|w| w.as_bool()))
            .unwrap_or(false)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.get(&format!("/stats?task={}", url_encode(task)))
            .and_then(|v| CacheStats::from_json(&v))
            .unwrap_or_default()
    }

    fn service_stats(&self) -> BackendStats {
        // Server-side aggregate, merged with the client-side degradation
        // counters (the server reports zeros for these — retries and
        // breaker transitions are a property of *this* binding).
        let mut stats = self
            .get("/stats")
            .and_then(|v| BackendStats::from_json(&v))
            .unwrap_or_default();
        stats.remote_retries += self.retries_counter.load(Ordering::Relaxed);
        stats.breaker_opens += self.breaker_opens.load(Ordering::Relaxed);
        stats.breaker_half_opens += self.breaker_half_opens.load(Ordering::Relaxed);
        stats.breaker_closes += self.breaker_closes.load(Ordering::Relaxed);
        stats.failovers += self.failovers_counter.load(Ordering::Relaxed);
        stats.epoch_rejects += self.epoch_rejects_counter.load(Ordering::Relaxed);
        stats
    }

    fn persist(&self, dir: &str) -> bool {
        // `dir` names a path on the *server's* filesystem.
        let body = Json::obj(vec![("dir", Json::str(dir))]).to_string();
        self.post("/persist", body)
            .and_then(|v| v.get("ok").and_then(|o| o.as_bool()))
            .unwrap_or(false)
    }

    fn warm_start(&self, dir: &str) -> bool {
        let body = Json::obj(vec![("dir", Json::str(dir))]).to_string();
        self.post("/warm_start", body)
            .and_then(|v| v.get("ok").and_then(|o| o.as_bool()))
            .unwrap_or(false)
    }

    /// `true` while the circuit breaker is open: executors bypass the
    /// cache entirely, which means no organic traffic would ever probe
    /// for recovery — so once the cooldown elapses, *this* call performs
    /// the half-open probe inline (a single bounded `/ping` round trip;
    /// any HTTP answer closes the breaker).
    fn degraded(&self) -> bool {
        match self.breaker.load(Ordering::Acquire) {
            BREAKER_CLOSED => false,
            BREAKER_HALF_OPEN => true, // someone else's probe is in flight
            _ => {
                if self.opened_at.lock().unwrap().elapsed() >= self.cfg.breaker_cooldown
                    && self.try_half_open()
                {
                    match self.with_client(|c| c.get("/ping")) {
                        Ok(_) => {
                            self.note_success();
                            false
                        }
                        Err(_) => {
                            self.note_transport_failure();
                            true
                        }
                    }
                } else {
                    true
                }
            }
        }
    }
}

impl SessionBackend for RemoteBinding {
    /// One `/capabilities` round trip, once per binding (not per session,
    /// not per request). Only a *definitive* server answer is cached for
    /// the binding's lifetime: a v2 handshake caches the advertised set,
    /// and a sub-5xx non-200 answer (a pre-v2 server 404s the endpoint)
    /// caches [`Capabilities::LEGACY`]. A transport failure or 5xx also
    /// reports `LEGACY` — the session opening right now still degrades
    /// safely — but leaves the cache unset, so the *next* session open
    /// re-probes instead of pinning the whole run to the degraded
    /// protocol. An already-negotiated binding never flaps: the cached
    /// answer wins.
    fn capabilities(&self) -> Capabilities {
        if let Some(c) = *self.caps.lock().unwrap() {
            return c;
        }
        match self.post_bin_status("/capabilities", true, |buf| {
            wire::enc_hello(buf, Capabilities::PROTO_V2)
        }) {
            Ok((200, body)) => match wire::dec_caps_resp(&body) {
                Some((_proto, caps)) => {
                    *self.caps.lock().unwrap() = Some(caps);
                    caps
                }
                // A 200 that doesn't decode is a garbled frame, not a
                // definitive answer — degrade now, re-probe next open.
                None => Capabilities::LEGACY,
            },
            Ok((status, _)) if status < 500 => {
                // Definitive: the server answered and it has no v2
                // handshake (a pre-v2 server 404s the endpoint). Cache
                // the downgrade.
                *self.caps.lock().unwrap() = Some(Capabilities::LEGACY);
                Capabilities::LEGACY
            }
            // A 5xx is the server having a bad moment, not a protocol
            // answer — degrade this open, re-probe on the next.
            Ok(_) | Err(_) => Capabilities::LEGACY,
        }
    }

    /// Bumped on every failover. Sessions holding cursors seeded on the
    /// old server observe the change and re-seed on the new one — cursor
    /// tables are per-server state and do not survive promotion.
    fn backend_generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    fn cursor_open(&self, task: &str) -> u64 {
        self.post_bin("/cursor_open", false, |buf| wire::enc_cursor_open(buf, task))
            .as_deref()
            .and_then(wire::dec_u64_resp)
            .unwrap_or(0)
    }

    fn cursor_step(&self, task: &str, cursor: u64, call: &ToolCall) -> CursorStep {
        // The O(1) hot frame: only the delta call crosses the wire. A
        // transport failure reports `Invalid`, which the executor treats
        // as "fall back to a full-prefix lookup" — the same degradation
        // ladder as a server-side eviction.
        self.post_bin("/cursor_step", false, |buf| {
            wire::enc_cursor_step(buf, task, cursor, call)
        })
        .as_deref()
        .and_then(wire::dec_step_resp)
        .unwrap_or(CursorStep::Invalid)
    }

    fn cursor_record(
        &self,
        task: &str,
        cursor: u64,
        call: &ToolCall,
        result: &ToolResult,
    ) -> Option<NodeId> {
        self.post_bin("/cursor_record", false, |buf| {
            wire::enc_cursor_record(buf, task, cursor, call, result)
        })
        .as_deref()
        .and_then(wire::dec_u64_resp)
        .map(|n| n as usize)
    }

    fn cursor_seek(&self, task: &str, cursor: u64, node: NodeId, steps: usize) -> bool {
        self.post_bin("/cursor_seek", true, |buf| {
            wire::enc_cursor_seek(buf, task, cursor, node, steps)
        })
        .as_deref()
        .and_then(wire::dec_bool_resp)
        .unwrap_or(false)
    }

    fn cursor_close(&self, task: &str, cursor: u64) {
        let _ =
            self.post_bin("/cursor_close", true, |buf| wire::enc_cursor_close(buf, task, cursor));
    }

    /// Session-owned pin release. Not retried: a lost response leaves the
    /// pin registered on the server-side session entry, which releases it
    /// at close/sweep — bounded by the session lifetime instead of leaked
    /// forever (the failure mode that forced the legacy wire protocol to
    /// unpin offers before replying).
    fn session_release(&self, task: &str, cursor: u64, node: NodeId) {
        let _ = self.post_bin("/session_release", false, |buf| {
            wire::enc_session_release(buf, task, cursor, node)
        });
    }

    /// One reasoning turn, one round trip (`/session_turn`). Never retried
    /// transparently — a replayed step/record would double-apply; a lost
    /// response degrades through [`TurnReply::refused`] into the same
    /// `Invalid`-fallback ladder as a server-side eviction.
    fn session_turn(&self, task: &str, cursor: u64, batch: &TurnBatch) -> TurnReply {
        self.post_bin("/session_turn", false, |buf| {
            wire::enc_turn(buf, task, cursor, batch)
        })
        .as_deref()
        .and_then(wire::dec_turn_resp)
        .unwrap_or_else(|| TurnReply::refused(batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A localhost port with nothing listening: dials get an immediate
    /// ECONNREFUSED (no fault plan needed, so safe in concurrent tests).
    fn dead_addr() -> std::net::SocketAddr {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        drop(l);
        addr
    }

    fn fast_cfg() -> BindingConfig {
        BindingConfig {
            connect_timeout: Duration::from_millis(200),
            read_timeout: Duration::from_millis(500),
            retries: 1,
            backoff_base: Duration::from_millis(1),
            backoff_max: Duration::from_millis(2),
            breaker_threshold: 3,
            // Large enough that no half-open probe fires mid-test (the
            // recovery path is covered by the fault-injection suite).
            breaker_cooldown: Duration::from_secs(60),
            seed: 1,
            // Probe gating is exercised by its own test below; everything
            // else wants the pre-gate behavior.
            probe_cooldown: Duration::ZERO,
            endpoints: Vec::new(),
        }
    }

    #[test]
    fn breaker_trips_after_consecutive_failures_and_fast_fails() {
        let b = RemoteBinding::connect_with(dead_addr(), fast_cfg());
        assert_eq!(b.breaker_state(), "closed");
        for _ in 0..3 {
            assert!(b.insert("t", &[]).is_none());
        }
        assert_eq!(b.breaker_state(), "open");
        assert!(b.degraded());
        // Open breaker: requests fast-fail without touching the network.
        let t0 = Instant::now();
        for _ in 0..50 {
            assert!(b.insert("t", &[]).is_none());
        }
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "open breaker must fast-fail, took {:?}",
            t0.elapsed()
        );
        let stats = b.service_stats();
        assert_eq!(stats.breaker_opens, 1);
        assert!(stats.remote_retries >= 3, "{}", stats.remote_retries);
    }

    #[test]
    fn failed_insert_is_none_not_root() {
        let b = RemoteBinding::connect_with(dead_addr(), fast_cfg());
        assert_eq!(b.insert("t", &[]), None);
        let call = ToolCall::stateless("x", "1");
        let result = ToolResult::new("out", 0.0);
        assert_eq!(b.cursor_record("t", 1, &call, &result), None);
    }

    #[test]
    fn transport_failure_does_not_cache_legacy_capabilities() {
        let b = RemoteBinding::connect_with(dead_addr(), fast_cfg());
        assert_eq!(b.capabilities(), Capabilities::LEGACY);
        // Not cached: a later probe (server now reachable) may upgrade.
        assert!(b.caps.lock().unwrap().is_none());
    }

    #[test]
    fn promote_probe_cooldown_bounds_flapping() {
        use std::sync::Arc;
        // A candidate follower that refuses every promotion: without the
        // probe cooldown, each breaker re-open would hit it with another
        // `/promote`.
        let promotes = Arc::new(AtomicU64::new(0));
        let seen = promotes.clone();
        let candidate = crate::util::http::Server::bind(
            "127.0.0.1:0",
            2,
            Arc::new(move |req: &crate::util::http::Request| {
                if req.path == "/promote" {
                    seen.fetch_add(1, Ordering::Relaxed);
                    crate::util::http::Response::text_static(503, "not promotable")
                } else {
                    crate::util::http::Response::not_found()
                }
            }),
        )
        .unwrap();
        let cfg = BindingConfig {
            breaker_threshold: 1,
            // Flap fast: each degraded() poll past this re-opens and
            // re-enters try_failover.
            breaker_cooldown: Duration::from_millis(5),
            probe_cooldown: Duration::from_secs(60),
            endpoints: vec![candidate.addr()],
            ..fast_cfg()
        };
        let b = RemoteBinding::connect_with(dead_addr(), cfg);
        // First failure trips the breaker and spends the one allowed probe.
        assert!(b.insert("t", &[]).is_none());
        assert_eq!(promotes.load(Ordering::Relaxed), 1);
        // Every later flap (half-open /ping probe fails against the dead
        // primary → re-open → try_failover) finds the candidate inside its
        // probe cooldown and skips it.
        for _ in 0..5 {
            std::thread::sleep(Duration::from_millis(6));
            assert!(b.degraded());
        }
        assert_eq!(
            promotes.load(Ordering::Relaxed),
            1,
            "cooldown must bound promote probes under flapping"
        );
        assert_eq!(b.failovers(), 0);
    }

    #[test]
    fn backoff_is_bounded_and_jittered() {
        let b = RemoteBinding::connect_with(dead_addr(), fast_cfg());
        for attempt in 1..8 {
            let d = b.backoff(attempt);
            assert!(d <= b.cfg.backoff_max, "attempt {attempt}: {d:?}");
            assert!(d >= b.cfg.backoff_base / 2, "attempt {attempt}: {d:?}");
        }
    }
}
