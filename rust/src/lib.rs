//! TVCACHE — a stateful tool-value cache for RL post-training of LLM agents.
//!
//! Reproduction of "TVCACHE: A Stateful Tool-Value Cache for Post-Training
//! LLM Agents" (CS.LG 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   tool-call-graph cache, longest-prefix matching, selective sandbox
//!   snapshotting, fork orchestration, the HTTP cache server/client, and the
//!   RL post-training driver.
//! * **Layer 2 (python/compile/model.py)** — the agent policy network (a
//!   small causal transformer) and its GRPO/REINFORCE training step, written
//!   in JAX and AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (fused causal
//!   attention, RMSNorm) called from the Layer-2 graphs.
//!
//! Python never runs on the post-training hot path: `make artifacts` lowers
//! the JAX graphs once, and [`runtime`] loads and executes them through the
//! PJRT C API (`xla` crate).

pub mod util;
pub mod sim;
pub mod cache;
pub mod wire;
pub mod sandbox;
pub mod server;
pub mod client;
pub mod cluster;
pub mod agent;
pub mod workloads;
pub mod train;
pub mod runtime;
pub mod metrics;
pub mod bench;
