//! Agents: scripted stochastic policies (workload simulation) and the
//! tool-action vocabulary used by the PJRT transformer policy.
//!
//! The scripted agents are calibrated to reproduce each workload's
//! *cross-rollout redundancy statistics* — which is what cache hit rates
//! depend on (DESIGN.md §3): rollouts for a task mostly follow a canonical
//! tool script and diverge stochastically at branch points.

pub mod action;
pub mod scripted;

pub use action::ActionSpace;
pub use scripted::{Agent, Script, ScriptedAgent};
