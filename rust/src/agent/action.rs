//! Tool-action vocabulary: the token space of the PJRT transformer policy.
//!
//! The Layer-2 model emits one token per step; each token is either a
//! control token (BOS / STOP / ANSWER_k) or one tool invocation from a
//! per-workload action set. This flattening keeps generation one forward
//! pass per tool call, which is what makes on-CPU RL post-training feasible
//! (DESIGN.md §Hardware-Adaptation) while preserving the structure the
//! paper cares about: the policy's token sequence *is* the tool trajectory.

use crate::cache::ToolCall;
use crate::sandbox::TerminalTask;

/// Token ids: 0 = BOS, 1 = STOP/submit, 2..=6 = ANSWER_0..4, 7.. = actions.
pub const BOS: i32 = 0;
pub const STOP: i32 = 1;
pub const ANSWER_BASE: i32 = 2;
pub const N_ANSWERS: i32 = 5;
pub const ACTION_BASE: i32 = ANSWER_BASE + N_ANSWERS;

/// A per-task action space mapping token ids to tool calls.
pub struct ActionSpace {
    actions: Vec<ToolCall>,
    pub vocab: usize,
}

impl ActionSpace {
    /// The terminal-task action space: the commands a debugging agent needs
    /// (explore, install, build, test, patch variants).
    pub fn terminal(task: &TerminalTask) -> ActionSpace {
        let b = |cmd: String, mutates: bool| ToolCall::with_flag("bash", cmd, mutates);
        let buggy = &task.buggy_file;
        let mut actions = vec![
            b("cat README.md".into(), false),
            b(format!("cat {buggy}"), false),
            b("ls".into(), false),
            b("cat Makefile".into(), false),
            b("make".into(), true),
            b("make test".into(), true),
            b(format!("patch {buggy} s/{}/{}/", task.bug_pattern, task.fix_pattern), true),
            b(format!("patch {buggy} s/{}/return x * 3/", task.bug_pattern), true),
            b("echo done > status.txt".into(), true),
        ];
        if let Some(dep) = &task.required_package {
            actions.push(b(format!("pip install {dep}"), true));
        }
        let vocab = ACTION_BASE as usize + actions.len();
        ActionSpace { actions, vocab }
    }

    /// Number of valid actions.
    pub fn len(&self) -> usize {
        self.actions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    /// Decode a token into a tool call (None for control tokens).
    pub fn decode(&self, token: i32) -> Option<&ToolCall> {
        if token < ACTION_BASE {
            return None;
        }
        self.actions.get((token - ACTION_BASE) as usize)
    }

    /// Encode an action index to a token.
    pub fn token_of(&self, action_idx: usize) -> i32 {
        ACTION_BASE + action_idx as i32
    }

    /// Is `token` a terminal token (STOP or an answer)?
    pub fn is_terminal(token: i32) -> bool {
        token == STOP || (ANSWER_BASE..ANSWER_BASE + N_ANSWERS).contains(&token)
    }

    /// Mask of valid next tokens (logits outside are forced to -inf by the
    /// sampler): the model may answer/stop or take any action, never BOS.
    pub fn valid_tokens(&self, model_vocab: usize) -> Vec<bool> {
        let mut mask = vec![false; model_vocab];
        for t in 1..(ACTION_BASE as usize + self.actions.len()).min(model_vocab) {
            mask[t] = true;
        }
        mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_control_tokens_is_none() {
        let space = ActionSpace::terminal(&TerminalTask::generate(1, false));
        assert!(space.decode(BOS).is_none());
        assert!(space.decode(STOP).is_none());
        assert!(space.decode(ANSWER_BASE).is_none());
    }

    #[test]
    fn decode_roundtrip() {
        let space = ActionSpace::terminal(&TerminalTask::generate(1, false));
        for i in 0..space.len() {
            let tok = space.token_of(i);
            let call = space.decode(tok).unwrap();
            assert_eq!(call, &space.actions[i]);
        }
        assert!(space.decode(space.token_of(space.len())).is_none());
    }

    #[test]
    fn vocab_fits_actions() {
        let space = ActionSpace::terminal(&TerminalTask::generate(3, true)); // medium: has dep
        assert_eq!(space.vocab, ACTION_BASE as usize + space.len());
        assert!(space.actions.iter().any(|a| a.args.starts_with("pip install")));
    }

    #[test]
    fn valid_token_mask_shape() {
        let space = ActionSpace::terminal(&TerminalTask::generate(1, false));
        let mask = space.valid_tokens(64);
        assert_eq!(mask.len(), 64);
        assert!(!mask[BOS as usize]);
        assert!(mask[STOP as usize]);
        assert!(mask[space.token_of(0) as usize]);
        assert!(!mask[space.token_of(space.len()) as usize]);
    }

    #[test]
    fn terminal_tokens_detected() {
        assert!(ActionSpace::is_terminal(STOP));
        assert!(ActionSpace::is_terminal(ANSWER_BASE + 2));
        assert!(!ActionSpace::is_terminal(BOS));
        assert!(!ActionSpace::is_terminal(ACTION_BASE));
    }
}
