//! Scripted stochastic agents for the three workloads.

use crate::cache::ToolCall;
use crate::util::rng::Rng;

/// Minimal agent interface: given the trajectory so far (and its outputs),
/// emit the next tool call, or `None` to stop and answer.
pub trait Agent: Send {
    fn next_call(&mut self, history: &[(ToolCall, String)]) -> Option<ToolCall>;
    /// The agent's final answer (graded by the reward function).
    fn final_answer(&self) -> String;
}

/// Which workload script to follow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Script {
    Terminal { medium: bool },
    Sql,
    Ego,
}

/// A stochastic, script-following agent.
///
/// * `competence` — probability of taking the canonical (correct) action at
///   each branch point; rollouts of a better/larger model use a higher
///   value (the paper observes larger models repeat tool calls more,
///   yielding higher hit rates — §4.1).
/// * Exploration draws come from a *small pool* of alternatives per
///   position, so parallel rollouts overlap heavily — the redundancy
///   TVCACHE exploits.
pub struct ScriptedAgent {
    script: Script,
    task_seed: u64,
    rng: Rng,
    competence: f64,
    step: usize,
    /// Plan: materialized call sequence for this rollout.
    plan: Vec<ToolCall>,
    answer: String,
}

impl ScriptedAgent {
    pub fn new(script: Script, task_seed: u64, rollout_seed: u64, competence: f64) -> Self {
        let mut rng = Rng::new(task_seed.rotate_left(17) ^ rollout_seed.wrapping_mul(0x2545F491));
        let (plan, answer) = match script {
            Script::Terminal { medium } => plan_terminal(task_seed, medium, &mut rng, competence),
            Script::Sql => plan_sql(task_seed, &mut rng, competence),
            Script::Ego => plan_ego(task_seed, &mut rng, competence),
        };
        ScriptedAgent { script, task_seed, rng, competence, step: 0, plan, answer }
    }

    pub fn plan_len(&self) -> usize {
        self.plan.len()
    }
}

impl Agent for ScriptedAgent {
    fn next_call(&mut self, _history: &[(ToolCall, String)]) -> Option<ToolCall> {
        let call = self.plan.get(self.step).cloned();
        self.step += 1;
        call
    }

    fn final_answer(&self) -> String {
        self.answer.clone()
    }
}

fn bash(cmd: impl Into<String>) -> ToolCall {
    let cmd = cmd.into();
    let stateless = cmd.starts_with("cat ")
        || cmd.starts_with("ls")
        || cmd.starts_with("grep ")
        || cmd.starts_with("pwd");
    ToolCall::with_flag("bash", cmd, !stateless)
}

/// Canonical terminal-bench debugging script with stochastic branches.
fn plan_terminal(
    task_seed: u64,
    medium: bool,
    rng: &mut Rng,
    competence: f64,
) -> (Vec<ToolCall>, String) {
    let task = crate::sandbox::TerminalTask::generate(task_seed, medium);
    let buggy = &task.buggy_file;
    let mut plan = Vec::new();

    // Exploration phase: canonical is README then the buggy file; the small
    // alternative pool keeps cross-rollout overlap high.
    plan.push(bash("cat README.md"));
    if rng.chance(competence) {
        plan.push(bash(format!("cat {buggy}")));
    } else {
        let alts = ["ls", "cat Makefile", "cat tests/test_module.py"];
        plan.push(bash(alts[rng.below(alts.len() as u64) as usize]));
        plan.push(bash(format!("cat {buggy}")));
    }

    // Real LLM agents emit idiosyncratic free-text commands (scratch notes,
    // varied greps) that rarely repeat across rollouts; each one forks the
    // TCG and makes the rollout's subsequent mutating calls misses. Where
    // the divergence lands decides how much of the expensive
    // install/build/test prefix stays cacheable — mixing positions keeps
    // hit rates in the paper's 15–32% terminal band (Appendix F).
    let uniq = rng.below(100_000);
    let probe_early = rng.chance(0.45);
    if probe_early {
        // A mutating scratch-note: forks the TCG before the expensive
        // build/test prefix, so this rollout re-executes it (miss).
        plan.push(bash(format!("echo probe-{uniq} >> debug.log")));
    }

    // Dependency install (medium tasks always need it).
    if let Some(dep) = &task.required_package {
        if rng.chance(competence) {
            plan.push(bash(format!("pip install {dep}")));
        } else {
            // Build first, see the error, then install: one extra miss.
            plan.push(bash("make"));
            plan.push(bash(format!("pip install {dep}")));
        }
    }
    plan.push(bash("make"));
    plan.push(bash("make test"));

    if !probe_early {
        // A unique *read* while diagnosing: a miss when first executed, but
        // stateless — it doesn't fork the TCG (Appendix B), so later
        // expensive calls can still hit.
        plan.push(bash(format!("grep probe{uniq} {buggy}")));
    }
    if rng.chance(0.5) {
        let words = ["return", "def", "assert", "import", "compute", "TODO"];
        plan.push(bash(format!(
            "grep {} {buggy}",
            words[rng.below(words.len() as u64) as usize]
        )));
    }

    // Patch phase: the canonical fix or a wrong guess first.
    let correct = rng.chance(competence);
    if !correct {
        let wrong = format!("patch {buggy} s/{}/return x * 3/", task.bug_pattern);
        plan.push(bash(wrong));
        plan.push(bash("make"));
        plan.push(bash("make test"));
        // Revert and apply the right one (only sometimes succeeds).
        plan.push(bash(format!("patch {buggy} s/return x * 3/{}/", task.fix_pattern)));
    } else {
        plan.push(bash(format!("patch {buggy} s/{}/{}/", task.bug_pattern, task.fix_pattern)));
    }
    plan.push(bash("make"));
    plan.push(bash("make test"));

    // Medium tasks do extra verification steps.
    if medium {
        plan.push(bash("python ./run --verify"));
        if rng.chance(0.5) {
            plan.push(bash(format!("grep return {buggy}")));
        }
    }
    let answer = if correct || rng.chance(0.4) { "fixed" } else { "gave-up" };
    (plan, answer.to_string())
}

/// SQL exploration + solve script.
fn plan_sql(task_seed: u64, rng: &mut Rng, competence: f64) -> (Vec<ToolCall>, String) {
    let sql = |q: &str| ToolCall::stateless("sql", q);
    // A small per-task pool of exploration queries (schema peeks).
    let pool = [
        "SELECT * FROM animals LIMIT 5",
        "SELECT COUNT(*) FROM animals",
        "SELECT * FROM customers LIMIT 5",
        "SELECT COUNT(*) FROM orders",
        "SELECT * FROM orders LIMIT 5",
        "SELECT COUNT(*) FROM customers",
    ];
    let golden = golden_sql(task_seed);
    let mut plan = Vec::new();
    let n_explore = 1 + rng.below(3) as usize;
    for _ in 0..n_explore {
        if rng.chance(0.3) {
            plan.push(sql(pool[rng.below(pool.len() as u64) as usize]));
        } else {
            // Free-form exploration with rollout-specific constants — the
            // long tail of distinct queries that keeps the paper's SQL hit
            // rate in the 27–57% band rather than saturating.
            let tables = ["animals", "orders", "customers"];
            let t = tables[rng.below(3) as usize];
            let limit = 3 + rng.below(200);
            plan.push(sql(&format!("SELECT * FROM {t} LIMIT {limit}")));
        }
    }
    let correct = rng.chance(competence);
    if !correct {
        // A near-miss query first (small pool ⇒ often repeated).
        let wrong = [
            "SELECT COUNT(*) FROM animals WHERE species = 'cow'",
            "SELECT COUNT(*) FROM orders WHERE status = 'open'",
            "SELECT AVG(age) FROM customers",
        ];
        plan.push(sql(wrong[rng.below(3) as usize]));
    }
    plan.push(sql(&golden));
    (plan, if correct { golden } else { "wrong".into() })
}

/// The golden query for a SQL task (reward compares against its output).
pub fn golden_sql(task_seed: u64) -> String {
    let golden_pool = [
        "SELECT COUNT(*) FROM animals WHERE species = 'pig'",
        "SELECT COUNT(*) FROM orders WHERE status = 'shipped'",
        "SELECT COUNT(*) FROM customers WHERE region = 'north'",
        "SELECT AVG(amount) FROM orders",
        "SELECT COUNT(*) FROM customers WHERE age > 40",
    ];
    golden_pool[(task_seed % golden_pool.len() as u64) as usize].to_string()
}

/// EgoSchema video-QA script (Appendix D tool mix).
fn plan_ego(task_seed: u64, rng: &mut Rng, competence: f64) -> (Vec<ToolCall>, String) {
    let mut plan = Vec::new();
    // The prompt mandates load → preprocess first; models learn this in the
    // first few rollouts (Appendix D) — competence gates it here.
    plan.push(ToolCall::new("load_video", format!("video_{task_seed}.mp4")));
    plan.push(ToolCall::new("preprocess", ""));

    let n_queries = 2 + rng.below(4) as usize;
    for _ in 0..n_queries {
        let r = rng.f64();
        if r < 0.35 {
            // caption_retrieval: integer args from a small pool ⇒ high reuse
            // (Figure 12: the highest hit rate among query tools).
            let starts = [0usize, 10, 20, 30, 40, 60];
            let a = starts[rng.below(6) as usize];
            plan.push(ToolCall::stateless("caption_retrieval", format!("({}, {})", a, a + 10)));
        } else if r < 0.6 {
            // segment_localization: small description pool.
            let descs = ["person cutting", "washing hands", "using phone", "cooking"];
            plan.push(ToolCall::stateless(
                "segment_localization",
                descs[rng.below(4) as usize],
            ));
        } else if r < 0.85 {
            // visual_qna: free-form string args ⇒ low hit rate (Fig 12).
            let seg = rng.below(90);
            plan.push(ToolCall::stateless(
                "visual_question_answering",
                format!("('what is the person doing at moment {}?', {seg})", rng.below(1000)),
            ));
        } else {
            // object_memory_querying: free-form, rarely repeated, slowest.
            plan.push(ToolCall::stateless(
                "object_memory_querying",
                format!("how many people appear near object {}?", rng.below(500)),
            ));
        }
    }
    // Ground truth answer is seed-derived; competence decides correctness.
    let truth = (task_seed % 5).to_string();
    let answer = if rng.chance(competence) {
        truth
    } else {
        ((task_seed + 1 + rng.below(4)) % 5).to_string()
    };
    (plan, answer)
}

/// Ground-truth EgoSchema answer for a task.
pub fn ego_truth(task_seed: u64) -> String {
    (task_seed % 5).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_of(script: Script, task: u64, rollout: u64, comp: f64) -> Vec<ToolCall> {
        let mut a = ScriptedAgent::new(script, task, rollout, comp);
        let mut out = Vec::new();
        while let Some(c) = a.next_call(&[]) {
            out.push(c);
        }
        out
    }

    #[test]
    fn plans_are_deterministic_per_seeds() {
        let a = plan_of(Script::Terminal { medium: false }, 3, 7, 0.6);
        let b = plan_of(Script::Terminal { medium: false }, 3, 7, 0.6);
        assert_eq!(a, b);
    }

    #[test]
    fn rollouts_share_prefixes_but_diverge() {
        let plans: Vec<_> =
            (0..8).map(|r| plan_of(Script::Terminal { medium: false }, 3, r, 0.6)).collect();
        // All rollouts start with the canonical first call.
        for p in &plans {
            assert_eq!(p[0].args, "cat README.md");
        }
        // But at least two distinct full plans exist.
        let distinct: std::collections::HashSet<_> =
            plans.iter().map(|p| format!("{p:?}")).collect();
        assert!(distinct.len() >= 2, "all 8 rollouts identical");
    }

    #[test]
    fn higher_competence_increases_overlap() {
        let overlap = |comp: f64| {
            let plans: Vec<_> =
                (0..16).map(|r| plan_of(Script::Terminal { medium: false }, 5, r, comp)).collect();
            let distinct: std::collections::HashSet<_> =
                plans.iter().map(|p| format!("{p:?}")).collect();
            16 - distinct.len() // more duplicates = more overlap
        };
        assert!(overlap(0.95) >= overlap(0.3), "competence should concentrate plans");
    }

    #[test]
    fn ego_plans_start_with_load_preprocess() {
        for r in 0..5 {
            let p = plan_of(Script::Ego, 9, r, 0.7);
            assert_eq!(p[0].tool, "load_video");
            assert_eq!(p[1].tool, "preprocess");
            assert!(p[0].mutates_state && p[1].mutates_state);
            for c in &p[2..] {
                assert!(!c.mutates_state, "{c:?} should be stateless");
            }
        }
    }

    #[test]
    fn sql_plans_are_all_stateless_and_end_with_answer() {
        let mut a = ScriptedAgent::new(Script::Sql, 4, 2, 1.0);
        let mut calls = Vec::new();
        while let Some(c) = a.next_call(&[]) {
            assert!(!c.mutates_state);
            assert_eq!(c.tool, "sql");
            calls.push(c);
        }
        assert_eq!(calls.last().unwrap().args, golden_sql(4));
        assert_eq!(a.final_answer(), golden_sql(4));
    }

    #[test]
    fn terminal_competent_agent_fixes_bug() {
        // A fully-competent agent's plan must include the correct patch.
        let task = crate::sandbox::TerminalTask::generate(11, false);
        let p = plan_of(Script::Terminal { medium: false }, 11, 0, 1.0);
        assert!(
            p.iter().any(|c| c.args.contains(&task.fix_pattern)),
            "plan lacks the fix: {p:?}"
        );
    }
}
