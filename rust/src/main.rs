//! TVCACHE launcher.
//!
//! ```text
//! tvcache serve    --addr 127.0.0.1:8117 --workers 8 --shards 8
//!                  [--replicate-window N]          # keep an op-log for followers
//!                  [--follow HOST:PORT]            # tail a primary as a warm follower
//!                  [--follow-tick-ms N]            # follower idle tick (default 5)
//!                  [--wal-dir PATH]                # durable op-log + crash recovery
//!                  [--wal-segment-bytes N]         # WAL segment rotation size
//!                  [--wal-fsync-every N]           # group-fsync record threshold
//! tvcache workload --name terminal-easy|terminal-medium|sql|ego
//!                  [--tasks N] [--epochs N] [--shards N] [--no-cache]
//! ```

use std::sync::Arc;

use tvcache::bench::print_table;
use tvcache::cache::{
    ServiceConfig, ShardedCacheService, TaskCache, DEFAULT_FSYNC_EVERY, DEFAULT_SEGMENT_BYTES,
};
use tvcache::server::{serve_follower_with_tick, serve_service, DEFAULT_SHARDS};
use tvcache::train::{run_workload, SimOptions};
use tvcache::util::cli::Args;
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => {
            let addr = args.str_or("addr", "127.0.0.1:8117");
            let workers = args.usize_or("workers", 8);
            let shards = args.usize_or("shards", DEFAULT_SHARDS);
            let window = match args.get("replicate-window") {
                Some(w) => Some(w.parse::<usize>()?),
                None => None,
            };
            let sharded = ShardedCacheService::with_config(
                ServiceConfig {
                    shards,
                    replicate_window: window,
                    wal_dir: args.get("wal-dir").map(std::path::PathBuf::from),
                    wal_segment_bytes: args
                        .usize_or("wal-segment-bytes", DEFAULT_SEGMENT_BYTES as usize)
                        as u64,
                    wal_fsync_every: args.usize_or("wal-fsync-every", DEFAULT_FSYNC_EVERY as usize)
                        as u64,
                    ..Default::default()
                },
                Arc::new(TaskCache::with_defaults),
            )?;
            let (server, svc) = match args.get("follow") {
                Some(primary) => {
                    let primary: std::net::SocketAddr = primary.parse()?;
                    let tick =
                        std::time::Duration::from_millis(args.usize_or("follow-tick-ms", 5) as u64);
                    serve_follower_with_tick(&addr, workers, sharded, primary, tick)?
                }
                None => serve_service(&addr, workers, sharded)?,
            };
            println!(
                "tvcache {} listening on {} ({} shards, epoch {})",
                if svc.is_follower() { "follower" } else { "server" },
                server.addr(),
                svc.shard_count(),
                svc.epoch()
            );
            println!(
                "endpoints: /get /prefix_match /put /release /cursor_open /cursor_step \
                 /cursor_record /cursor_seek /cursor_close /capabilities /session_turn \
                 /session_release /snapshot /warm /persist /warm_start /stats /viz /ping \
                 /replicate /bootstrap /promote /drain"
            );
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Some("workload") => {
            let name = args.str_or("name", "terminal-easy");
            let workload = match name.as_str() {
                "terminal-easy" => Workload::TerminalEasy,
                "terminal-medium" => Workload::TerminalMedium,
                "sql" => Workload::SkyRlSql,
                "ego" => Workload::EgoSchema,
                other => return Err(format!("unknown workload {other}").into()),
            };
            let cfg = WorkloadConfig::config_for(workload);
            let mut opts =
                SimOptions::from_config(&cfg, args.usize_or("tasks", 8), !args.bool("no-cache"));
            opts.epochs = args.usize_or("epochs", cfg.epochs);
            opts.shards = args.usize_or("shards", opts.shards);
            let m = run_workload(&cfg, &opts);
            let rows: Vec<Vec<String>> = m
                .epoch_hit_rates
                .iter()
                .zip(&m.epoch_rewards)
                .map(|((e, hr), (_, rw))| {
                    vec![format!("{e}"), format!("{:.1}%", hr * 100.0), format!("{rw:.3}")]
                })
                .collect();
            print_table(
                &format!("{name} ({} tasks, cache={})", opts.n_tasks, opts.cached),
                &["epoch", "hit_rate", "mean_reward"],
                &rows,
            );
            println!(
                "\noverall hit rate {:.1}%, median tool call {:.3}s",
                100.0 * m.overall_hit_rate(),
                m.median_call_time()
            );
            Ok(())
        }
        _ => {
            println!("usage: tvcache <serve|workload> [flags]   (see README)");
            Ok(())
        }
    }
}
