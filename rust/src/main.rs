//! TVCACHE launcher.
//!
//! ```text
//! tvcache serve    --addr 127.0.0.1:8117 --workers 8 --shards 8
//!                  [--replicate-window N]          # keep an op-log for followers
//!                  [--follow HOST:PORT]            # tail a primary as a warm follower
//!                  [--follow-tick-ms N]            # follower idle tick (default 5)
//!                  [--wal-dir PATH]                # durable op-log + crash recovery
//!                  [--wal-segment-bytes N]         # WAL segment rotation size
//!                  [--wal-fsync-every N]           # group-fsync record threshold
//!                  [--node-id ID]                  # cluster identity (e.g. g0/primary)
//!                  [--cluster-map cluster.json]    # arm the placement guard
//! tvcache workload --name terminal-easy|terminal-medium|sql|ego
//!                  [--tasks N] [--epochs N] [--shards N] [--no-cache]
//! tvcache cluster  --map cluster.json              # parse/validate/print the map
//!                  [--serve HOST:PORT]             # fan-in /cluster_stats status server
//! ```

use std::sync::Arc;

use tvcache::bench::print_table;
use tvcache::cache::{
    ServiceConfig, ShardedCacheService, TaskCache, DEFAULT_FSYNC_EVERY, DEFAULT_SEGMENT_BYTES,
};
use tvcache::client::BindingConfig;
use tvcache::cluster::{ClusterMap, ClusterRouter};
use tvcache::server::{serve_follower_with_tick, serve_service, DEFAULT_SHARDS};
use tvcache::train::{run_workload, SimOptions};
use tvcache::util::cli::Args;
use tvcache::util::http::{Handler, Request, Response, Server};
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => {
            let addr = args.str_or("addr", "127.0.0.1:8117");
            let workers = args.usize_or("workers", 8);
            let shards = args.usize_or("shards", DEFAULT_SHARDS);
            let window = match args.get("replicate-window") {
                Some(w) => Some(w.parse::<usize>()?),
                None => None,
            };
            let sharded = ShardedCacheService::with_config(
                ServiceConfig {
                    shards,
                    replicate_window: window,
                    wal_dir: args.get("wal-dir").map(std::path::PathBuf::from),
                    wal_segment_bytes: args
                        .usize_or("wal-segment-bytes", DEFAULT_SEGMENT_BYTES as usize)
                        as u64,
                    wal_fsync_every: args.usize_or("wal-fsync-every", DEFAULT_FSYNC_EVERY as usize)
                        as u64,
                    ..Default::default()
                },
                Arc::new(TaskCache::with_defaults),
            )?;
            let (server, svc) = match args.get("follow") {
                Some(primary) => {
                    let primary: std::net::SocketAddr = primary.parse()?;
                    let tick =
                        std::time::Duration::from_millis(args.usize_or("follow-tick-ms", 5) as u64);
                    serve_follower_with_tick(&addr, workers, sharded, primary, tick)?
                }
                None => serve_service(&addr, workers, sharded)?,
            };
            if let Some(id) = args.get("node-id") {
                svc.set_node_id(id);
            }
            if let Some(path) = args.get("cluster-map") {
                let map = ClusterMap::parse(&std::fs::read_to_string(path)?)?;
                let Some(id) = svc.node_id() else {
                    return Err("--cluster-map requires --node-id".into());
                };
                let Some((group, _)) = map.locate(id) else {
                    return Err(format!("node id {id:?} is not in {path}").into());
                };
                let name = map.groups()[group].name.clone();
                svc.set_cluster_guard(map, group);
                println!("cluster guard armed: node {id} serves group {name}");
            }
            println!(
                "tvcache {} listening on {} ({} shards, epoch {})",
                if svc.is_follower() { "follower" } else { "server" },
                server.addr(),
                svc.shard_count(),
                svc.epoch()
            );
            println!(
                "endpoints: /get /prefix_match /put /release /cursor_open /cursor_step \
                 /cursor_record /cursor_seek /cursor_close /capabilities /session_turn \
                 /session_release /snapshot /warm /persist /warm_start /stats /viz /ping \
                 /replicate /bootstrap /promote /drain"
            );
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Some("workload") => {
            let name = args.str_or("name", "terminal-easy");
            let workload = match name.as_str() {
                "terminal-easy" => Workload::TerminalEasy,
                "terminal-medium" => Workload::TerminalMedium,
                "sql" => Workload::SkyRlSql,
                "ego" => Workload::EgoSchema,
                other => return Err(format!("unknown workload {other}").into()),
            };
            let cfg = WorkloadConfig::config_for(workload);
            let mut opts =
                SimOptions::from_config(&cfg, args.usize_or("tasks", 8), !args.bool("no-cache"));
            opts.epochs = args.usize_or("epochs", cfg.epochs);
            opts.shards = args.usize_or("shards", opts.shards);
            let m = run_workload(&cfg, &opts);
            let rows: Vec<Vec<String>> = m
                .epoch_hit_rates
                .iter()
                .zip(&m.epoch_rewards)
                .map(|((e, hr), (_, rw))| {
                    vec![format!("{e}"), format!("{:.1}%", hr * 100.0), format!("{rw:.3}")]
                })
                .collect();
            print_table(
                &format!("{name} ({} tasks, cache={})", opts.n_tasks, opts.cached),
                &["epoch", "hit_rate", "mean_reward"],
                &rows,
            );
            println!(
                "\noverall hit rate {:.1}%, median tool call {:.3}s",
                100.0 * m.overall_hit_rate(),
                m.median_call_time()
            );
            Ok(())
        }
        Some("cluster") => {
            let Some(path) = args.get("map") else {
                return Err("cluster: missing --map cluster.json".into());
            };
            let map = ClusterMap::parse(&std::fs::read_to_string(path)?)?;
            // Arc-share sample: place a synthetic task population and
            // report each group's slice, so an imbalanced map is visible
            // before any node is launched.
            const SAMPLE: usize = 10_000;
            let mut counts = vec![0usize; map.groups().len()];
            for t in 0..SAMPLE {
                counts[map.group_for(&format!("task-{t}"))] += 1;
            }
            let rows: Vec<Vec<String>> = map
                .groups()
                .iter()
                .zip(&counts)
                .map(|(g, &n)| {
                    vec![
                        g.name.clone(),
                        g.primary.to_string(),
                        g.follower.map(|f| f.to_string()).unwrap_or_else(|| "-".into()),
                        g.primary_id(),
                        format!("{:.1}%", 100.0 * n as f64 / SAMPLE as f64),
                    ]
                })
                .collect();
            print_table(
                &format!(
                    "{path}: {} groups, {} vnodes, seed {}",
                    map.groups().len(),
                    map.vnodes(),
                    map.seed()
                ),
                &["group", "primary", "follower", "node id", "share"],
                &rows,
            );
            println!(
                "\nlaunch each node with `tvcache serve --node-id <group>/primary|follower \
                 --cluster-map {path}` (followers add --follow <primary>)"
            );
            if let Some(status_addr) = args.get("serve") {
                let router =
                    Arc::new(ClusterRouter::connect(map, BindingConfig::default()));
                let handler: Handler = Arc::new(move |req: &Request| {
                    match (req.method.as_str(), req.path.as_str()) {
                        ("GET", "/ping") => Response::text_static(200, "pong"),
                        ("GET", "/cluster_stats") => {
                            Response::json(router.cluster_stats().to_json().to_string())
                        }
                        _ => Response::not_found(),
                    }
                });
                let server = Server::bind(status_addr, 2, handler)?;
                println!("cluster status server on {} (GET /cluster_stats)", server.addr());
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(3600));
                }
            }
            Ok(())
        }
        _ => {
            println!("usage: tvcache <serve|workload|cluster> [flags]   (see README)");
            Ok(())
        }
    }
}
