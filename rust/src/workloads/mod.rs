//! Workload definitions: the paper's three evaluation workloads with the
//! Table 1 configurations, task generators, and reward functions
//! (Appendix C scheme: -1 bad format, 0 wrong answer, +1 correct).

use std::sync::Arc;

use crate::agent::{Script, ScriptedAgent};
use crate::cache::ToolCall;
use crate::sandbox::{SandboxFactory, SqlFactory, TerminalFactory, VideoFactory};

/// The workloads of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    TerminalEasy,
    TerminalMedium,
    SkyRlSql,
    EgoSchema,
}

/// One post-training configuration row of Table 1.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub workload: Workload,
    pub agent_name: &'static str,
    /// Competence of the scripted policy (proxy for model quality; larger
    /// models repeat tool calls more — §4.1).
    pub competence: f64,
    pub n_tasks: usize,
    pub epochs: usize,
    pub rollouts: usize,
    /// Reasoning-token generation rate (tok/s) for the gen-time model.
    pub tokens_per_sec: f64,
    /// Mean reasoning tokens emitted before each tool call.
    pub tokens_per_step: f64,
}

impl WorkloadConfig {
    /// The six rows of Table 1.
    pub fn table1() -> Vec<WorkloadConfig> {
        vec![
            WorkloadConfig {
                workload: Workload::TerminalEasy,
                agent_name: "Qwen3-4B-Instruct-2507",
                competence: 0.55,
                n_tasks: 51,
                epochs: 10,
                rollouts: 8,
                tokens_per_sec: 85.0,
                tokens_per_step: 950.0,
            },
            WorkloadConfig {
                workload: Workload::TerminalMedium,
                agent_name: "Qwen3-4B-Instruct-2507",
                competence: 0.5,
                n_tasks: 95,
                epochs: 10,
                rollouts: 8,
                tokens_per_sec: 85.0,
                tokens_per_step: 1500.0,
            },
            WorkloadConfig {
                workload: Workload::TerminalEasy,
                agent_name: "Qwen3-14B-Instruct",
                competence: 0.75,
                n_tasks: 51,
                epochs: 10,
                rollouts: 4,
                tokens_per_sec: 45.0,
                tokens_per_step: 500.0,
            },
            WorkloadConfig {
                workload: Workload::TerminalMedium,
                agent_name: "Qwen3-14B-Instruct",
                competence: 0.7,
                n_tasks: 95,
                epochs: 10,
                rollouts: 4,
                tokens_per_sec: 45.0,
                tokens_per_step: 900.0,
            },
            WorkloadConfig {
                workload: Workload::SkyRlSql,
                agent_name: "Qwen2.5-Coder-7B-Instruct",
                competence: 0.6,
                n_tasks: 653,
                epochs: 10,
                rollouts: 5,
                tokens_per_sec: 60.0,
                tokens_per_step: 55.0,
            },
            WorkloadConfig {
                workload: Workload::EgoSchema,
                agent_name: "Qwen3-30B-A3B-Instruct-2507",
                competence: 0.65,
                n_tasks: 100,
                epochs: 5,
                rollouts: 8,
                tokens_per_sec: 55.0,
                tokens_per_step: 1050.0,
            },
        ]
    }

    pub fn config_for(workload: Workload) -> WorkloadConfig {
        Self::table1().into_iter().find(|c| c.workload == workload).unwrap()
    }

    pub fn script(&self) -> Script {
        match self.workload {
            Workload::TerminalEasy => Script::Terminal { medium: false },
            Workload::TerminalMedium => Script::Terminal { medium: true },
            Workload::SkyRlSql => Script::Sql,
            Workload::EgoSchema => Script::Ego,
        }
    }

    pub fn factory(&self) -> Arc<dyn SandboxFactory> {
        match self.workload {
            Workload::TerminalEasy => Arc::new(TerminalFactory { medium: false }),
            Workload::TerminalMedium => Arc::new(TerminalFactory { medium: true }),
            Workload::SkyRlSql => Arc::new(SqlFactory),
            Workload::EgoSchema => Arc::new(VideoFactory),
        }
    }

    /// Snapshotting is unnecessary for the read-only SQL workload (§4.2).
    pub fn snapshot_policy(&self) -> crate::cache::SnapshotPolicy {
        match self.workload {
            Workload::SkyRlSql => crate::cache::SnapshotPolicy::never(),
            _ => crate::cache::SnapshotPolicy::default(),
        }
    }

    pub fn agent(&self, task_seed: u64, rollout_seed: u64) -> ScriptedAgent {
        ScriptedAgent::new(self.script(), task_seed, rollout_seed, self.competence)
    }

    /// Appendix C reward: -1 bad format, 0 wrong, +1 correct.
    pub fn reward(
        &self,
        task_seed: u64,
        trajectory: &[(ToolCall, String)],
        final_answer: &str,
    ) -> f64 {
        // Format errors are simulated upstream; a missing trajectory counts.
        if trajectory.is_empty() {
            return -1.0;
        }
        let correct = match self.workload {
            Workload::TerminalEasy | Workload::TerminalMedium => trajectory
                .iter()
                .any(|(c, out)| c.args.starts_with("make test") && out.contains("12 passed")),
            Workload::SkyRlSql => {
                // Correct iff the final answer is the golden query (whose
                // output, on the same DB, is by construction the target).
                final_answer == crate::agent::scripted::golden_sql(task_seed)
            }
            Workload::EgoSchema => final_answer == crate::agent::scripted::ego_truth(task_seed),
        };
        if correct {
            1.0
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::ToolResult;

    #[test]
    fn table1_has_six_rows() {
        let rows = WorkloadConfig::table1();
        assert_eq!(rows.len(), 6);
        assert_eq!(rows[5].epochs, 5); // EgoSchema trains 5 epochs
        assert_eq!(rows[4].n_tasks, 653); // SkyRL-SQL task count
    }

    #[test]
    fn terminal_reward_follows_test_output() {
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let good = vec![(
            ToolCall::new("bash", "make test"),
            "ran 12 tests: 12 passed".to_string(),
        )];
        let bad = vec![(
            ToolCall::new("bash", "make test"),
            "ran 12 tests: 11 passed, 1 FAILED".to_string(),
        )];
        assert_eq!(cfg.reward(1, &good, ""), 1.0);
        assert_eq!(cfg.reward(1, &bad, ""), 0.0);
        assert_eq!(cfg.reward(1, &[], ""), -1.0);
    }

    #[test]
    fn sql_reward_checks_golden_answer() {
        let cfg = WorkloadConfig::config_for(Workload::SkyRlSql);
        let traj = vec![(ToolCall::stateless("sql", "SELECT 1"), "1".to_string())];
        let golden = crate::agent::scripted::golden_sql(7);
        assert_eq!(cfg.reward(7, &traj, &golden), 1.0);
        assert_eq!(cfg.reward(7, &traj, "SELECT nope"), 0.0);
    }

    #[test]
    fn competent_terminal_rollout_earns_reward_end_to_end() {
        // Run a fully-competent scripted agent through a real sandbox and
        // check the reward fires — agents, sandbox, and reward compose.
        let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
        let mut agent = ScriptedAgent::new(cfg.script(), 11, 0, 1.0);
        let factory = cfg.factory();
        let mut sb = factory.create(11);
        let mut traj: Vec<(ToolCall, String)> = Vec::new();
        use crate::agent::scripted::Agent as _;
        while let Some(call) = agent.next_call(&traj) {
            let ToolResult { output, .. } = sb.execute(&call);
            traj.push((call, output));
        }
        assert_eq!(cfg.reward(11, &traj, &agent.final_answer()), 1.0, "{traj:?}");
    }

    #[test]
    fn sql_workload_disables_snapshotting() {
        let cfg = WorkloadConfig::config_for(Workload::SkyRlSql);
        assert!(cfg.snapshot_policy().disabled);
        let cfg2 = WorkloadConfig::config_for(Workload::TerminalEasy);
        assert!(!cfg2.snapshot_policy().disabled);
    }
}
