//! Minimal JSON implementation (value model, parser, serializer).
//!
//! The offline toolchain has no `serde`/`serde_json`, and the TVCACHE wire
//! protocol (Figure 4: `/get`, `/put`, `/prefix_match`) plus `meta.json`
//! parsing only need a compact, correct JSON core — so we build one.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is deterministic
/// (useful for cache keys and golden tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

/// Serialize a number exactly as `Json::Num` does (integer form for whole
/// values below 1e15). Public so direct-to-string serializers (e.g.
/// `ToolResult::json_into`) stay byte-identical with the tree serializer.
pub fn write_num(n: f64, out: &mut String) {
    use std::fmt::Write;
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

/// Append `s` as a quoted, escaped JSON string — the escaping `Json::Str`
/// uses, exposed for serializers that build strings without a `Json` tree.
pub fn escape_str(s: &str, out: &mut String) {
    write_escaped(s, out)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document (must consume the full input modulo whitespace).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{text}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse the low half if present.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 5;
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 2..self.pos + 6],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 1; // net adjust below
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    self.pos += 4;
                                    char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                self.pos += 4;
                                char::from_u32(cp).unwrap_or('\u{FFFD}')
                            };
                            out.push(ch);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x"], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"key":"val","n":3,"nested":{"a":[1,2,3],"b":true}}"#,
            r#"[1,2.5,"x",null,{"y":[]}]"#,
            r#""quote \" backslash \\ newline \n""#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café 😀""#).unwrap();
        assert_eq!(v, Json::Str("café 😀".into()));
        let s = Json::Str("tab\t\"q\"".into()).to_string();
        assert_eq!(parse(&s).unwrap().as_str().unwrap(), "tab\t\"q\"");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let a = parse(r#"{"z":1,"a":2}"#).unwrap().to_string();
        let b = parse(r#"{"a":2,"z":1}"#).unwrap().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
