//! Streaming histograms and summary statistics.
//!
//! Every paper figure is a distribution or a percentile series; this module
//! provides the exact-percentile (sorted-sample) summaries used by the bench
//! harness and the log-bucketed histogram used online by the cache server's
//! latency stats.

/// Exact-sample summary: keeps all observations, computes percentiles by
/// sorting on demand. Fine for bench-scale sample counts.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.sum() / self.xs.len() as f64
        }
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile in `[0, 100]` by nearest-rank on the sorted sample.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let n = self.xs.len();
        let rank = ((p / 100.0) * (n as f64 - 1.0)).round() as usize;
        self.xs[rank.min(n - 1)]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Log-bucketed histogram: O(1) insert, ~4% relative error on percentiles.
/// Used on the server hot path where keeping every sample would allocate.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// buckets[i] counts values in [base * 1.04^i, base * 1.04^(i+1))
    buckets: Vec<u64>,
    base: f64,
    growth: f64,
    count: u64,
    sum: f64,
    overflow: u64,
}

impl LogHistogram {
    /// `base` = smallest resolvable value (e.g. 1e-6 seconds).
    pub fn new(base: f64) -> Self {
        LogHistogram {
            buckets: vec![0; 1024],
            base,
            growth: 1.04f64.ln(),
            count: 0,
            sum: 0.0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.base {
            self.buckets[0] += 1;
            return;
        }
        let idx = ((x / self.base).ln() / self.growth) as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate percentile (upper bucket bound).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * ((i as f64 + 1.0) * self.growth).exp();
            }
        }
        f64::INFINITY // answered by the overflow bucket
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.overflow += other.overflow;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.median() - 50.5).abs() <= 0.5); // nearest-rank: 50 or 51
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(95.0) - 95.0).abs() <= 1.0);
        assert_eq!(s.mean(), 50.5);
    }

    #[test]
    fn samples_empty_is_zero() {
        let mut s = Samples::new();
        assert_eq!(s.median(), 0.0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn log_histogram_accuracy() {
        let mut h = LogHistogram::new(1e-6);
        let mut s = Samples::new();
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..20_000 {
            let x = rng.lognormal(-4.0, 1.5);
            h.add(x);
            s.add(x);
        }
        for p in [50.0, 90.0, 95.0, 99.0] {
            let exact = s.percentile(p);
            let approx = h.percentile(p);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.08, "p{p}: exact {exact} approx {approx}");
        }
    }

    #[test]
    fn log_histogram_merge() {
        let mut a = LogHistogram::new(1e-6);
        let mut b = LogHistogram::new(1e-6);
        for i in 1..=100 {
            a.add(i as f64 * 1e-3);
            b.add(i as f64 * 1e-3);
        }
        let solo_p50 = a.percentile(50.0);
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!((a.percentile(50.0) - solo_p50).abs() / solo_p50 < 0.05);
    }
}
