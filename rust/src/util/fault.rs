//! Deterministic, seeded fault injection for the transport and spill seams.
//!
//! The cache is an *optimization, never a correctness dependency*: a flaky
//! or dead cache service must degrade rollouts to plain tool execution, not
//! stall or corrupt them. This module makes that claim continuously
//! testable: a [`FaultPlan`] installed via [`install`] arms probabilistic
//! faults at three seams —
//!
//! * the **HTTP transport** ([`connect_error`], [`send_error`],
//!   [`recv_fault`] on the client; [`server_reply`] in the server's
//!   connection loop): connection drops, delays past the read deadline,
//!   partial writes, garbled frames, injected 5xx;
//! * **spill-tier filesystem I/O** ([`spill_write_error`],
//!   [`spill_read_fails`]): short writes / ENOSPC on the write path, read
//!   errors on fault-in;
//! * **background workers** ([`worker_stall`]): stalled eviction/sweep
//!   ticks;
//! * the **replication seam** ([`replicate_fails`]): failed follower
//!   pulls of the primary's op-log;
//! * **WAL file I/O** ([`wal_write_error`], [`wal_torn_write`],
//!   [`wal_garble_write`]): failed appends, records torn mid-write, and
//!   garbled (CRC-failing) records — each trips the WAL's sticky degraded
//!   mode so the corruption stays a recoverable tail.
//!
//! Faults are drawn from one seeded [`Rng`], so a single-threaded driver
//! replays the exact same fault sequence for a given seed; concurrent
//! drivers get a reproducible *distribution* (draw order then depends on
//! thread interleaving). Every injected fault is counted per seam
//! ([`injected`], [`injected_total`]) and surfaced through
//! `BackendStats::injected_faults`.
//!
//! The hooks are compiled into release builds (the chaos CI job runs the
//! suite under `--release`) but cost a single relaxed atomic load when no
//! plan is installed. Installation is process-global, so [`install`] also
//! serializes: the returned [`FaultScope`] holds a global lock for its
//! lifetime, which keeps concurrently-running fault tests from arming each
//! other's faults.

use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use super::rng::Rng;

/// Where a fault was injected (indexes the per-seam counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Seam {
    /// Client `TcpStream::connect` refused/failed.
    Connect = 0,
    /// Client-side connection drop while sending the request.
    ClientSend = 1,
    /// Client-side drop or garble while receiving the response.
    ClientRecv = 2,
    /// Server-side reply fault (drop / partial write / 5xx / garble /
    /// stall past the client deadline).
    ServerReply = 3,
    /// Spill-tier write failure (short write / ENOSPC / torn rename).
    SpillWrite = 4,
    /// Spill-tier read failure on fault-in.
    SpillRead = 5,
    /// Background eviction/sweep worker tick stalled.
    WorkerTick = 6,
    /// Follower replication pull failed (tail loop retries next tick).
    Replicate = 7,
    /// WAL append fault: failed write, torn (partial) record, or garbled
    /// CRC — all sticky-degrade the durable log.
    WalWrite = 8,
}

/// Number of [`Seam`] variants (length of the counter table).
pub const SEAM_COUNT: usize = 9;

/// Per-seam fault probabilities plus the PRNG seed. All probabilities
/// default to zero; a test arms only the seams it is exercising.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed for the injector's PRNG (fault decisions replay per seed).
    pub seed: u64,
    /// P(client connect attempt fails outright).
    pub p_connect_fail: f64,
    /// P(connection drops while the client writes the request).
    pub p_send_drop: f64,
    /// P(connection drops while the client reads the response).
    pub p_recv_drop: f64,
    /// P(the received response body is corrupted in flight).
    pub p_recv_garble: f64,
    /// P(server closes the connection without replying).
    pub p_server_drop: f64,
    /// P(server writes only part of the response, then closes).
    pub p_server_partial: f64,
    /// P(server answers 500 instead of the real response).
    pub p_server_500: f64,
    /// P(server corrupts the response body).
    pub p_server_garble: f64,
    /// P(server stalls for [`FaultPlan::server_stall`] before replying —
    /// push this past the client read deadline to exercise timeouts).
    pub p_server_stall: f64,
    /// How long a stalled server reply sleeps.
    pub server_stall: Duration,
    /// P(a spill-tier payload/manifest write fails — simulated ENOSPC).
    pub p_spill_write_fail: f64,
    /// P(a spill-tier payload read fails on fault-in).
    pub p_spill_read_fail: f64,
    /// P(a background worker tick stalls for [`FaultPlan::worker_stall`]).
    pub p_worker_stall: f64,
    /// How long a stalled worker tick sleeps.
    pub worker_stall: Duration,
    /// P(a follower's `/replicate` pull fails — the tail loop skips the
    /// tick and retries, so lag grows until a pull lands).
    pub p_replicate_fail: f64,
    /// P(a WAL append's write fails outright — nothing lands on disk).
    pub p_wal_write_fail: f64,
    /// P(a WAL record is torn mid-write — only a prefix of the frame
    /// lands, exactly what a crash between `write` calls leaves behind).
    pub p_wal_torn_tail: f64,
    /// P(a WAL record's payload is corrupted on the way to disk, so its
    /// CRC fails on recovery).
    pub p_wal_garble: f64,
    /// Restrict injection to the installing thread. Lib unit tests set
    /// this so a scope can never leak faults into unrelated tests running
    /// concurrently in the same process; the dedicated fault-injection
    /// integration binary leaves it `false` because server pool threads
    /// and background workers must see the faults too (there, every test
    /// installs a scope, which serializes the whole binary).
    pub thread_scoped: bool,
}

impl FaultPlan {
    /// A plan with every probability at zero (arm seams field-by-field).
    pub fn quiet(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            p_connect_fail: 0.0,
            p_send_drop: 0.0,
            p_recv_drop: 0.0,
            p_recv_garble: 0.0,
            p_server_drop: 0.0,
            p_server_partial: 0.0,
            p_server_500: 0.0,
            p_server_garble: 0.0,
            p_server_stall: 0.0,
            server_stall: Duration::from_millis(100),
            p_spill_write_fail: 0.0,
            p_spill_read_fail: 0.0,
            p_worker_stall: 0.0,
            worker_stall: Duration::from_millis(50),
            p_replicate_fail: 0.0,
            p_wal_write_fail: 0.0,
            p_wal_torn_tail: 0.0,
            p_wal_garble: 0.0,
            thread_scoped: false,
        }
    }

    /// Like [`FaultPlan::quiet`], but injection is limited to the calling
    /// thread — safe to arm inside concurrently-running unit tests.
    pub fn quiet_local(seed: u64) -> FaultPlan {
        FaultPlan { thread_scoped: true, ..FaultPlan::quiet(seed) }
    }
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::quiet(0)
    }
}

/// What a server-side reply fault does to the response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerFault {
    /// Close the connection without writing anything.
    Drop,
    /// Write the head and a truncated body, then close.
    Partial,
    /// Replace the response with a 500.
    Error500,
    /// Corrupt the response body bytes.
    Garble,
    /// Sleep before replying (exceeds the client deadline when armed so).
    Stall(Duration),
}

struct FaultState {
    plan: FaultPlan,
    rng: Rng,
    owner: std::thread::ThreadId,
}

/// Fast-path gate: a single relaxed load when no plan is installed.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<FaultState>> = Mutex::new(None);
/// Serializes fault-test scopes process-wide (held by [`FaultScope`]).
static SCOPE: Mutex<()> = Mutex::new(());
/// Cumulative per-seam injection counts; monotonic for the process
/// lifetime so statistics never run backwards between scopes.
static COUNTS: [AtomicU64; SEAM_COUNT] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// Active fault installation; dropping it disarms every seam. Holds the
/// process-global scope lock, so concurrent fault tests serialize instead
/// of arming each other's faults.
pub struct FaultScope {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        ACTIVE.store(false, Ordering::SeqCst);
        *lock_state() = None;
    }
}

fn lock_state() -> MutexGuard<'static, Option<FaultState>> {
    // A panic inside a fault test poisons at worst a consistent state.
    STATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm `plan` process-wide until the returned scope drops.
pub fn install(plan: FaultPlan) -> FaultScope {
    let serial = SCOPE.lock().unwrap_or_else(|e| e.into_inner());
    let rng = Rng::new(plan.seed ^ 0xFA17_FA17_FA17_FA17);
    let owner = std::thread::current().id();
    *lock_state() = Some(FaultState { plan, rng, owner });
    ACTIVE.store(true, Ordering::SeqCst);
    FaultScope { _serial: serial }
}

/// Is any fault plan currently installed?
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Injected-fault count for one seam (cumulative for the process).
pub fn injected(seam: Seam) -> u64 {
    COUNTS[seam as usize].load(Ordering::Relaxed)
}

/// Total injected faults across all seams (cumulative for the process).
pub fn injected_total() -> u64 {
    COUNTS.iter().map(|c| c.load(Ordering::Relaxed)).sum()
}

fn note(seam: Seam) {
    COUNTS[seam as usize].fetch_add(1, Ordering::Relaxed);
}

/// Run `f` against the installed plan, if any. Probability rolls happen
/// under the state lock so the draw sequence is seed-deterministic.
fn with_plan<T>(f: impl FnOnce(&FaultPlan, &mut Rng) -> Option<T>) -> Option<T> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return None;
    }
    let mut guard = lock_state();
    let state = guard.as_mut()?;
    if state.plan.thread_scoped && std::thread::current().id() != state.owner {
        return None;
    }
    f(&state.plan, &mut state.rng)
}

fn roll(rng: &mut Rng, p: f64) -> bool {
    p > 0.0 && rng.f64() < p
}

/// Client connect seam: `Some(err)` aborts the dial.
pub fn connect_error() -> Option<io::Error> {
    with_plan(|plan, rng| roll(rng, plan.p_connect_fail).then_some(()))?;
    note(Seam::Connect);
    Some(io::Error::new(
        io::ErrorKind::ConnectionRefused,
        "injected connect failure",
    ))
}

/// Client send seam: `Some(err)` simulates the connection dropping before
/// the request is written.
pub fn send_error() -> Option<io::Error> {
    with_plan(|plan, rng| roll(rng, plan.p_send_drop).then_some(()))?;
    note(Seam::ClientSend);
    Some(io::Error::new(
        io::ErrorKind::ConnectionReset,
        "injected send drop",
    ))
}

/// Client receive seam, applied to a fully-read response body: may drop
/// the connection (`Err`) or garble the body in place.
pub fn recv_fault(body: &mut [u8]) -> io::Result<()> {
    enum RecvFault {
        Drop,
        Garble,
    }
    let fault = with_plan(|plan, rng| {
        if roll(rng, plan.p_recv_drop) {
            Some(RecvFault::Drop)
        } else if roll(rng, plan.p_recv_garble) {
            Some(RecvFault::Garble)
        } else {
            None
        }
    });
    match fault {
        Some(RecvFault::Drop) => {
            note(Seam::ClientRecv);
            Err(io::Error::new(
                io::ErrorKind::ConnectionReset,
                "injected recv drop",
            ))
        }
        Some(RecvFault::Garble) => {
            note(Seam::ClientRecv);
            garble(body);
            Ok(())
        }
        None => Ok(()),
    }
}

/// Server reply seam: the connection loop applies the returned fault to
/// the response it was about to write.
pub fn server_reply() -> Option<ServerFault> {
    let fault = with_plan(|plan, rng| {
        if roll(rng, plan.p_server_drop) {
            Some(ServerFault::Drop)
        } else if roll(rng, plan.p_server_partial) {
            Some(ServerFault::Partial)
        } else if roll(rng, plan.p_server_500) {
            Some(ServerFault::Error500)
        } else if roll(rng, plan.p_server_garble) {
            Some(ServerFault::Garble)
        } else if roll(rng, plan.p_server_stall) {
            Some(ServerFault::Stall(plan.server_stall))
        } else {
            None
        }
    })?;
    note(Seam::ServerReply);
    Some(fault)
}

/// Spill write seam: `Some(err)` fails the payload/manifest write (the
/// store treats it exactly like a real ENOSPC).
pub fn spill_write_error() -> Option<io::Error> {
    with_plan(|plan, rng| roll(rng, plan.p_spill_write_fail).then_some(()))?;
    note(Seam::SpillWrite);
    Some(io::Error::other("injected spill write failure (ENOSPC)"))
}

/// Spill read seam: `true` fails this fault-in (degrades to replay).
pub fn spill_read_fails() -> bool {
    if with_plan(|plan, rng| roll(rng, plan.p_spill_read_fail).then_some(())).is_some() {
        note(Seam::SpillRead);
        return true;
    }
    false
}

/// Worker tick seam: `Some(d)` stalls this background tick for `d`.
pub fn worker_stall() -> Option<Duration> {
    let d = with_plan(|plan, rng| roll(rng, plan.p_worker_stall).then_some(plan.worker_stall))?;
    note(Seam::WorkerTick);
    Some(d)
}

/// Replication seam: `true` fails this follower pull of the primary's
/// op-log (the tail loop retries next tick; lag grows until one lands).
pub fn replicate_fails() -> bool {
    if with_plan(|plan, rng| roll(rng, plan.p_replicate_fail).then_some(())).is_some() {
        note(Seam::Replicate);
        return true;
    }
    false
}

/// WAL append seam: `Some(err)` fails the write outright (nothing lands;
/// the WAL sticky-degrades, availability over durability).
pub fn wal_write_error() -> Option<io::Error> {
    with_plan(|plan, rng| roll(rng, plan.p_wal_write_fail).then_some(()))?;
    note(Seam::WalWrite);
    Some(io::Error::other("injected WAL write failure (ENOSPC)"))
}

/// WAL torn-write seam: `true` tears this record mid-write — only a
/// prefix of the frame lands, the shape a crash between `write` calls
/// leaves. Recovery must truncate it, never replay it.
pub fn wal_torn_write() -> bool {
    if with_plan(|plan, rng| roll(rng, plan.p_wal_torn_tail).then_some(())).is_some() {
        note(Seam::WalWrite);
        return true;
    }
    false
}

/// WAL garble seam: `true` corrupts this record's payload before it is
/// written, so its CRC fails on recovery.
pub fn wal_garble_write() -> bool {
    if with_plan(|plan, rng| roll(rng, plan.p_wal_garble).then_some(())).is_some() {
        note(Seam::WalWrite);
        return true;
    }
    false
}

/// Deterministic body corruption: enough to break any framed decode while
/// keeping the transport-visible length unchanged.
pub fn garble(body: &mut [u8]) {
    if body.is_empty() {
        return;
    }
    let last = body.len() - 1;
    body[0] ^= 0xA5;
    body[last / 2] ^= 0x5A;
    body[last] = body[last].wrapping_add(0x77);
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests avoid asserting on `active()` outside a held
    // scope — a sibling test may hold one concurrently. All plans here
    // are thread-scoped, so sibling scopes can never inject into us.

    #[test]
    fn disabled_injector_is_inert() {
        assert!(connect_error().is_none());
        assert!(send_error().is_none());
        assert!(server_reply().is_none());
        assert!(spill_write_error().is_none());
        assert!(!spill_read_fails());
        assert!(worker_stall().is_none());
        assert!(!replicate_fails());
        assert!(wal_write_error().is_none());
        assert!(!wal_torn_write());
        assert!(!wal_garble_write());
        let mut body = vec![1, 2, 3];
        assert!(recv_fault(&mut body).is_ok());
        assert_eq!(body, vec![1, 2, 3]);
    }

    #[test]
    fn scoped_install_arms_and_disarms() {
        {
            let mut plan = FaultPlan::quiet_local(7);
            plan.p_connect_fail = 1.0;
            let _scope = install(plan);
            assert!(active());
            let before = injected(Seam::Connect);
            assert!(connect_error().is_some());
            assert_eq!(injected(Seam::Connect), before + 1);
        }
        assert!(connect_error().is_none());
    }

    #[test]
    fn fault_sequence_replays_per_seed() {
        let drive = |seed: u64| -> Vec<bool> {
            let mut plan = FaultPlan::quiet_local(seed);
            plan.p_recv_drop = 0.5;
            let _scope = install(plan);
            (0..64)
                .map(|_| {
                    let mut body = vec![0u8; 4];
                    recv_fault(&mut body).is_err()
                })
                .collect()
        };
        let a = drive(42);
        let b = drive(42);
        let c = drive(43);
        assert_eq!(a, b, "same seed must replay the same fault sequence");
        assert_ne!(a, c, "different seeds must explore different sequences");
        assert!(a.iter().any(|&x| x) && !a.iter().all(|&x| x));
    }

    #[test]
    fn garble_always_changes_nonempty_bodies() {
        for n in 1..16 {
            let body: Vec<u8> = (0..n).collect();
            let mut garbled = body.clone();
            garble(&mut garbled);
            assert_eq!(garbled.len(), body.len());
            assert_ne!(garbled, body, "len {n}");
        }
        let mut empty: Vec<u8> = Vec::new();
        garble(&mut empty);
        assert!(empty.is_empty());
    }

    #[test]
    fn server_fault_kinds_all_reachable() {
        let mut seen_500 = false;
        let mut seen_drop = false;
        let mut plan = FaultPlan::quiet_local(9);
        plan.p_server_drop = 0.3;
        plan.p_server_500 = 0.3;
        let _scope = install(plan);
        for _ in 0..256 {
            match server_reply() {
                Some(ServerFault::Drop) => seen_drop = true,
                Some(ServerFault::Error500) => seen_500 = true,
                _ => {}
            }
        }
        assert!(seen_drop && seen_500);
    }
}
