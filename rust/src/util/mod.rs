//! From-scratch utility substrates: PRNG, JSON, HTTP, thread pool, CLI,
//! histograms. The offline toolchain ships no equivalents (no serde / tokio /
//! clap / rand / criterion), so TVCACHE builds its own — see DESIGN.md §4.

pub mod cli;
pub mod fault;
pub mod hist;
pub mod http;
pub mod json;
pub mod rng;
pub mod threadpool;
