//! Deterministic PRNG + sampling utilities (no `rand` crate in the offline
//! toolchain — this is the from-scratch substrate).
//!
//! [`Rng`] is Xoshiro256** seeded through SplitMix64: fast, well-distributed,
//! and reproducible across runs, which the discrete-event experiments rely on
//! (every paper figure regenerates bit-identically from its seed).

/// SplitMix64 step — used for seeding and as a cheap stateless hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the all-zero state (cannot happen via splitmix, but be safe).
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Rng { s }
    }

    /// Derive an independent stream for a labelled sub-task.
    pub fn fork(&self, label: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ label.wrapping_mul(0xA24B_AED4_963E_E407);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough reduction.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)` (f64).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given *underlying* mu/sigma. Heavy-tailed — used
    /// for tool-execution latency models (paper Fig 2 tails exceed 90%).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).max(1e-300).ln()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1) as u64) as usize;
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from a categorical distribution given by softmax(logits / temp).
    pub fn softmax_sample(&mut self, logits: &[f32], temperature: f32) -> usize {
        let t = temperature.max(1e-6);
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = logits
            .iter()
            .map(|&l| (((l - max) / t) as f64).exp())
            .collect();
        self.weighted(&weights)
    }
}

/// Stateless 64-bit hash of a byte string (FNV-1a), used for cache keys and
/// shard routing.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "{frac2}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
        // forked stream is itself deterministic
        let mut a2 = base.fork(0);
        let mut a3 = base.fork(0);
        assert_eq!(a2.next_u64(), a3.next_u64());
    }

    #[test]
    fn softmax_sample_prefers_large_logit() {
        let mut r = Rng::new(17);
        let logits = [0.0f32, 0.0, 8.0, 0.0];
        let mut hits = 0;
        for _ in 0..1000 {
            if r.softmax_sample(&logits, 1.0) == 2 {
                hits += 1;
            }
        }
        assert!(hits > 950, "{hits}");
    }

    #[test]
    fn fnv1a_stable() {
        assert_eq!(fnv1a(b"tvcache"), fnv1a(b"tvcache"));
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
