//! Fixed-size worker pool (the offline toolchain has no tokio/rayon).
//!
//! Used by the TVCACHE HTTP server for request handling and by the fork
//! pipeline for background sandbox instantiation (§3.3 "Background
//! instantiation").

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming jobs from a shared queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("tvcache-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(tx), workers }
    }

    /// Enqueue a job. Panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool is shut down")
            .send(Box::new(f))
            .expect("worker queue closed");
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel; workers drain the queue then exit.
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join drains the queue
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn zero_size_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.store(1, Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn jobs_run_concurrently() {
        use std::time::{Duration, Instant};
        let pool = ThreadPool::new(8);
        let start = Instant::now();
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                thread::sleep(Duration::from_millis(50));
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        // 8 sequential sleeps would be 400ms; concurrent should be well under.
        assert!(start.elapsed() < Duration::from_millis(350));
        assert_eq!(done.load(Ordering::SeqCst), 8);
    }
}
