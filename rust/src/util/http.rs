//! Minimal HTTP/1.1 server and blocking client over `std::net`.
//!
//! Backs the TVCACHE server (Figure 4): a thread-pooled listener dispatching
//! to a route handler, plus a keep-alive client used by `client::remote` and
//! the Figure 8 load generator. Supports exactly what the wire protocol
//! needs: methods, paths + query strings, `Content-Length` bodies,
//! keep-alive, and nothing more.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::fault;
use super::threadpool::ThreadPool;

/// Default client connect deadline: localhost dials either succeed or get
/// ECONNREFUSED within microseconds, so 2 s only matters when the peer is
/// genuinely unreachable (blackholed route, dead host).
pub const DEFAULT_CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// Default client read deadline per response.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(30);
/// Default per-connection server read deadline: a peer that connects and
/// then trickles (or never finishes) a request — the slowloris pattern —
/// is dropped after this long, freeing its pool worker.
pub const DEFAULT_SERVER_READ_DEADLINE: Duration = Duration::from_secs(30);

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: HashMap<String, String>,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }
}

/// An HTTP response under construction. The body is a `Cow` so constant
/// payloads (`"{}"`, `{"ok":true}`, error strings) are served from static
/// bytes instead of being re-allocated per request.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: std::borrow::Cow<'static, [u8]>,
}

impl Response {
    pub fn json(body: String) -> Response {
        Response {
            status: 200,
            content_type: "application/json",
            body: body.into_bytes().into(),
        }
    }

    /// A constant JSON payload — zero allocation per request.
    pub fn json_static(body: &'static str) -> Response {
        Response {
            status: 200,
            content_type: "application/json",
            body: body.as_bytes().into(),
        }
    }

    /// A binary-codec payload (`application/octet-stream`).
    pub fn binary(body: Vec<u8>) -> Response {
        Response {
            status: 200,
            content_type: "application/octet-stream",
            body: body.into(),
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain",
            body: body.into().into_bytes().into(),
        }
    }

    /// A constant plain-text response — zero allocation per request.
    pub fn text_static(status: u16, body: &'static str) -> Response {
        Response { status, content_type: "text/plain", body: body.as_bytes().into() }
    }

    pub fn not_found() -> Response {
        Response::text_static(404, "not found")
    }

    pub fn bad_request(msg: impl Into<String>) -> Response {
        Response::text(400, msg)
    }

    /// A constant bad-request response — zero allocation per request.
    pub fn bad_request_static(msg: &'static str) -> Response {
        Response::text_static(400, msg)
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            409 => "409 Conflict",
            421 => "421 Misdirected Request",
            500 => "500 Internal Server Error",
            503 => "503 Service Unavailable",
            _ => "200 OK",
        }
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync + 'static>;

/// A running HTTP server; dropping it stops the listener.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port) and serve `handler`
    /// on a pool of `workers` threads, with the default per-connection read
    /// deadline ([`DEFAULT_SERVER_READ_DEADLINE`]).
    pub fn bind(addr: &str, workers: usize, handler: Handler) -> std::io::Result<Server> {
        Self::bind_with(addr, workers, handler, DEFAULT_SERVER_READ_DEADLINE)
    }

    /// [`Server::bind`] with an explicit per-connection read deadline: any
    /// single blocking read (request line, header line, body chunk) that
    /// stalls past `read_deadline` drops the connection, so a slowloris
    /// peer can hold a pool worker for at most one deadline.
    pub fn bind_with(
        addr: &str,
        workers: usize,
        handler: Handler,
        read_deadline: Duration,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("tvcache-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = Arc::clone(&handler);
                            pool.execute(move || serve_connection(stream, h, read_deadline));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_connection(stream: TcpStream, handler: Handler, read_deadline: Duration) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(read_deadline));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    // Keep-alive loop: serve requests until the peer closes or errs.
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            _ => return,
        };
        let keep_alive = req
            .headers
            .get("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true);
        let mut resp = handler(&req);
        // Fault-injection seam: the handler has fully run (state mutations
        // committed), but the reply may be dropped, truncated, replaced
        // with a 5xx, corrupted, or stalled past the client deadline.
        match fault::server_reply() {
            Some(fault::ServerFault::Drop) => return,
            Some(fault::ServerFault::Partial) => {
                let _ = write_partial_response(&mut writer, &resp);
                return;
            }
            Some(fault::ServerFault::Error500) => {
                resp = Response::text_static(500, "injected server error");
            }
            Some(fault::ServerFault::Garble) => fault::garble(resp.body.to_mut()),
            Some(fault::ServerFault::Stall(d)) => std::thread::sleep(d),
            None => {}
        }
        if write_response(&mut writer, &resp, keep_alive).is_err() || !keep_alive {
            return;
        }
    }
}

fn read_request<R: BufRead>(reader: &mut R) -> std::io::Result<Option<Request>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None); // peer closed
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    if method.is_empty() || target.is_empty() {
        return Ok(None);
    }
    let (path, query) = split_target(&target);

    let mut headers = HashMap::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            return Ok(None);
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Some(Request { method, path, query, headers, body }))
}

fn split_target(target: &str) -> (String, HashMap<String, String>) {
    match target.split_once('?') {
        None => (target.to_string(), HashMap::new()),
        Some((p, q)) => {
            let mut map = HashMap::new();
            for pair in q.split('&') {
                if let Some((k, v)) = pair.split_once('=') {
                    map.insert(url_decode(k), url_decode(v));
                } else if !pair.is_empty() {
                    map.insert(url_decode(pair), String::new());
                }
            }
            (p.to_string(), map)
        }
    }
}

/// Percent-decoding (plus `+` as space).
pub fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' if i + 2 < bytes.len() + 1 && i + 2 < bytes.len() + 1 => {
                if i + 2 < bytes.len() {
                    if let Ok(v) =
                        u8::from_str_radix(std::str::from_utf8(&bytes[i + 1..i + 3]).unwrap_or(""), 16)
                    {
                        out.push(v);
                        i += 3;
                        continue;
                    }
                }
                out.push(b'%');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Percent-encoding for query values.
pub fn url_encode(s: &str) -> String {
    let mut out = String::new();
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

fn write_response(w: &mut TcpStream, resp: &Response, keep_alive: bool) -> std::io::Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len(),
        conn
    );
    w.write_all(head.as_bytes())?;
    w.write_all(&resp.body)?;
    w.flush()
}

/// Injected-fault variant of [`write_response`]: advertise the full
/// `Content-Length` but write only half the body, then close — the client
/// observes an `UnexpectedEof` mid-body.
fn write_partial_response(w: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len().max(2),
    );
    w.write_all(head.as_bytes())?;
    w.write_all(&resp.body[..resp.body.len() / 2])?;
    w.flush()
}

/// A blocking HTTP client with a persistent (keep-alive) connection. The
/// request-head buffer is reused across requests, so the steady-state
/// request path allocates nothing beyond what the caller's body needs.
///
/// Every request is bounded: dials use `TcpStream::connect_timeout` and
/// reads carry a socket read deadline, so a hung or blackholed server can
/// never block a caller indefinitely — the worst case is one deadline per
/// attempt, after which the caller sees an `io::Error` and degrades.
pub struct HttpClient {
    addr: SocketAddr,
    conn: Option<BufReader<TcpStream>>,
    head: String,
    connect_timeout: Duration,
    read_timeout: Duration,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> HttpClient {
        Self::with_deadlines(addr, DEFAULT_CONNECT_TIMEOUT, DEFAULT_READ_TIMEOUT)
    }

    /// Connect with explicit per-request connect/read deadlines.
    pub fn with_deadlines(
        addr: SocketAddr,
        connect_timeout: Duration,
        read_timeout: Duration,
    ) -> HttpClient {
        HttpClient { addr, conn: None, head: String::new(), connect_timeout, read_timeout }
    }

    fn ensure(&mut self) -> std::io::Result<&mut BufReader<TcpStream>> {
        if self.conn.is_none() {
            if let Some(e) = fault::connect_error() {
                return Err(e);
            }
            let stream = TcpStream::connect_timeout(&self.addr, self.connect_timeout)?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(self.read_timeout))?;
            self.conn = Some(BufReader::new(stream));
        }
        Ok(self.conn.as_mut().unwrap())
    }

    /// Issue a request; retries once on a stale keep-alive connection.
    pub fn request(
        &mut self,
        method: &str,
        path_and_query: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        for attempt in 0..2 {
            match self.try_request(method, path_and_query, body) {
                Ok(r) => return Ok(r),
                Err(e) if attempt == 0 => {
                    self.conn = None; // reconnect and retry once
                    let _ = e;
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!()
    }

    fn try_request(
        &mut self,
        method: &str,
        path_and_query: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        // Build the head in the reused buffer (taken out so the borrow of
        // `self.conn` below doesn't conflict; restored before returning).
        let mut head = std::mem::take(&mut self.head);
        head.clear();
        {
            use std::fmt::Write;
            let _ = write!(
                head,
                "{method} {path_and_query} HTTP/1.1\r\nHost: tvcache\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
                body.len()
            );
        }
        let out = self.try_request_with_head(&head, body);
        self.head = head;
        out
    }

    fn try_request_with_head(
        &mut self,
        head: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        if let Some(e) = fault::send_error() {
            self.conn = None;
            return Err(e);
        }
        let reader = self.ensure()?;
        {
            let stream = reader.get_mut();
            stream.write_all(head.as_bytes())?;
            stream.write_all(body)?;
            stream.flush()?;
        }
        // Status line
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "closed"));
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status"))?;
        // Headers
        let mut len = 0usize;
        let mut close = false;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let lower = h.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                len = v.trim().parse().unwrap_or(0);
            }
            if lower.starts_with("connection:") && lower.contains("close") {
                close = true;
            }
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body)?;
        if let Err(e) = fault::recv_fault(&mut body) {
            self.conn = None;
            return Err(e);
        }
        if close {
            self.conn = None;
        }
        Ok((status, body))
    }

    /// POST without the transparent stale-connection retry: for
    /// non-idempotent requests (cursor steps/records), where a replay
    /// after a lost response would apply the operation twice. Callers
    /// treat the error as a degraded outcome instead.
    pub fn post_once(&mut self, path: &str, body: &[u8]) -> std::io::Result<(u16, Vec<u8>)> {
        self.try_request("POST", path, body)
    }

    pub fn get(&mut self, path_and_query: &str) -> std::io::Result<(u16, Vec<u8>)> {
        self.request("GET", path_and_query, b"")
    }

    pub fn post(&mut self, path: &str, body: &[u8]) -> std::io::Result<(u16, Vec<u8>)> {
        self.request("POST", path, body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        let handler: Handler = Arc::new(|req: &Request| {
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/ping") => Response::text(200, "pong"),
                ("GET", "/q") => {
                    let v = req.query.get("k").cloned().unwrap_or_default();
                    Response::text(200, format!("k={v}"))
                }
                ("POST", "/echo") => Response::binary(req.body.clone()),
                _ => Response::not_found(),
            }
        });
        Server::bind("127.0.0.1:0", 2, handler).unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let server = echo_server();
        let mut c = HttpClient::connect(server.addr());
        let (status, body) = c.get("/ping").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"pong");
    }

    #[test]
    fn query_params_decoded() {
        let server = echo_server();
        let mut c = HttpClient::connect(server.addr());
        let (_, body) = c.get(&format!("/q?k={}", url_encode("a b/c"))).unwrap();
        assert_eq!(body, b"k=a b/c");
    }

    #[test]
    fn post_body_roundtrip_and_keepalive() {
        let server = echo_server();
        let mut c = HttpClient::connect(server.addr());
        for i in 0..10 {
            let payload = format!("payload-{i}-{}", "x".repeat(i * 100));
            let (status, body) = c.post("/echo", payload.as_bytes()).unwrap();
            assert_eq!(status, 200);
            assert_eq!(body, payload.as_bytes());
        }
    }

    #[test]
    fn unknown_path_404() {
        let server = echo_server();
        let mut c = HttpClient::connect(server.addr());
        let (status, _) = c.get("/nope").unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = HttpClient::connect(addr);
                    for _ in 0..20 {
                        let (s, b) = c.post("/echo", format!("t{i}").as_bytes()).unwrap();
                        assert_eq!(s, 200);
                        assert_eq!(b, format!("t{i}").as_bytes());
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn slowloris_connection_cannot_starve_the_pool() {
        // One worker, a 100 ms read deadline, and a peer that sends half a
        // request line then stalls forever: the deadline must free the
        // worker, so a well-formed request completes right after.
        let handler: Handler = Arc::new(|_req: &Request| Response::text(200, "ok"));
        let server =
            Server::bind_with("127.0.0.1:0", 1, handler, Duration::from_millis(100)).unwrap();
        let mut slow = TcpStream::connect(server.addr()).unwrap();
        slow.write_all(b"GET /pi").unwrap(); // never finished
        slow.flush().unwrap();
        let start = std::time::Instant::now();
        let mut c = HttpClient::connect(server.addr());
        let (status, body) = c.get("/anything").unwrap();
        assert_eq!((status, body.as_slice()), (200, b"ok".as_slice()));
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "stalled peer held the only worker past its read deadline"
        );
        drop(slow);
    }

    #[test]
    fn url_codec_roundtrip() {
        for s in ["hello", "a b+c", "tool:cat /foo.py", "ünïcødé 😀", "%%%"] {
            assert_eq!(url_decode(&url_encode(s)), s);
        }
    }
}
