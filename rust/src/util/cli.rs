//! Tiny CLI flag parser (`--key value` / `--flag` / positional args).
//!
//! The offline toolchain has no `clap`; the launcher and every bench binary
//! share this parser.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            args.flags.insert(key.to_string(), v);
                        }
                        _ => {
                            args.flags.insert(key.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse("train --epochs 10 --lr 0.003 --cache");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.u64_or("epochs", 0), 10);
        assert_eq!(a.f64_or("lr", 0.0), 0.003);
        assert!(a.bool("cache"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--out=results/x.csv --n=5");
        assert_eq!(a.str_or("out", ""), "results/x.csv");
        assert_eq!(a.usize_or("n", 0), 5);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("--verbose");
        assert!(a.bool("verbose"));
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse("--fast --steps 3");
        assert!(a.bool("fast"));
        assert_eq!(a.u64_or("steps", 0), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.str_or("x", "d"), "d");
        assert_eq!(a.u64_or("y", 7), 7);
    }
}
