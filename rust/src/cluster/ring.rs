//! The cluster map: a seeded consistent-hash ring with virtual nodes over
//! static replication-group membership.
//!
//! Placement must be a *pure function of the map*: every router and every
//! map-armed server derives the same `task → group` assignment from the
//! same `cluster.json`, with no coordination service in the loop. A
//! consistent-hash ring gives that, plus the property a plain
//! `hash % groups` lacks: when the operator edits the map to add or drop
//! a group, only the tasks on the affected arcs move — every other task's
//! cache (and its warm follower) stays exactly where it is.
//!
//! Each group claims [`ClusterMap::vnodes`] points on the ring, hashed
//! from `"{seed}/{name}/{v}"` with the same FNV-1a the in-process
//! [`crate::cache::ShardedCacheService`] shards with. A task lands on the
//! group owning the first ring point at or after `fnv1a(task)` (wrapping
//! past the top). Virtual nodes smooth the arc lengths: with 64 per group
//! the expected imbalance between groups is a few percent, not the 2–3×
//! swings single-point hashing produces.
//!
//! ```json
//! {
//!   "seed": 7,
//!   "vnodes": 64,
//!   "groups": [
//!     {"name": "g0", "primary": "10.0.0.1:8117", "follower": "10.0.0.2:8117"},
//!     {"name": "g1", "primary": "10.0.0.3:8117"}
//!   ]
//! }
//! ```
//!
//! `seed` and `vnodes` are optional (defaults `0` / [`DEFAULT_VNODES`]);
//! `follower` is optional per group. Node identities are derived, never
//! configured separately: `"{group}/primary"` and `"{group}/follower"` —
//! which is what `tvcache serve --node-id` should be launched with and
//! what the extended `/capabilities` handshake echoes back.

use std::net::SocketAddr;

use crate::util::json::{self, Json};
use crate::util::rng::fnv1a;

/// Default virtual nodes per group: enough to keep expected arc-length
/// imbalance in the low percent at negligible build cost (the ring is
/// built once per process and binary-searched per call).
pub const DEFAULT_VNODES: usize = 64;

/// One replication group: a primary and an optional warm follower, wired
/// together by the PR 8/9 op-log machinery outside this module's view.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupSpec {
    /// Unique group name (no `/` — node ids are `"{name}/{role}"`).
    pub name: String,
    pub primary: SocketAddr,
    pub follower: Option<SocketAddr>,
}

impl GroupSpec {
    /// The node identity the group's primary must be launched with.
    pub fn primary_id(&self) -> String {
        format!("{}/primary", self.name)
    }

    /// The node identity the group's follower must be launched with.
    pub fn follower_id(&self) -> String {
        format!("{}/follower", self.name)
    }
}

/// The static cluster map: groups plus the consistent-hash ring built
/// over them. Construction validates; placement ([`ClusterMap::group_for`])
/// is a pure function of the map, identical in every process that parsed
/// the same `cluster.json`.
#[derive(Debug, Clone)]
pub struct ClusterMap {
    seed: u64,
    vnodes: usize,
    groups: Vec<GroupSpec>,
    /// `(point, group index)`, sorted by point — the ring.
    ring: Vec<(u64, usize)>,
}

impl ClusterMap {
    /// Build and validate a map. Errors are operator-facing strings: this
    /// is the `cluster.json` validation surface.
    pub fn new(seed: u64, vnodes: usize, groups: Vec<GroupSpec>) -> Result<ClusterMap, String> {
        if groups.is_empty() {
            return Err("cluster map needs at least one group".into());
        }
        if vnodes == 0 {
            return Err("vnodes must be >= 1".into());
        }
        let mut endpoints: Vec<SocketAddr> = Vec::new();
        for (i, g) in groups.iter().enumerate() {
            if g.name.is_empty() {
                return Err(format!("group {i}: empty name"));
            }
            if g.name.contains('/') {
                return Err(format!("group {:?}: name must not contain '/'", g.name));
            }
            if groups[..i].iter().any(|prev| prev.name == g.name) {
                return Err(format!("duplicate group name {:?}", g.name));
            }
            for ep in std::iter::once(g.primary).chain(g.follower) {
                if endpoints.contains(&ep) {
                    return Err(format!("endpoint {ep} appears twice in the map"));
                }
                endpoints.push(ep);
            }
        }
        let mut ring = Vec::with_capacity(groups.len() * vnodes);
        for (idx, g) in groups.iter().enumerate() {
            for v in 0..vnodes {
                let point = fnv1a(format!("{seed}/{}/{v}", g.name).as_bytes());
                ring.push((point, idx));
            }
        }
        // Ties (two groups hashing to one point) are broken by group
        // index, deterministically — same order in every process.
        ring.sort_unstable();
        Ok(ClusterMap { seed, vnodes, groups, ring })
    }

    /// Parse a `cluster.json` document.
    pub fn parse(text: &str) -> Result<ClusterMap, String> {
        let doc = json::parse(text).map_err(|e| format!("bad cluster.json: {e}"))?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<ClusterMap, String> {
        let seed = doc.get("seed").and_then(|s| s.as_u64()).unwrap_or(0);
        let vnodes = doc
            .get("vnodes")
            .and_then(|v| v.as_u64())
            .map(|v| v as usize)
            .unwrap_or(DEFAULT_VNODES);
        let Some(entries) = doc.get("groups").and_then(|g| g.as_arr()) else {
            return Err("cluster.json: missing groups array".into());
        };
        let mut groups = Vec::with_capacity(entries.len());
        for (i, entry) in entries.iter().enumerate() {
            let Some(name) = entry.get("name").and_then(|n| n.as_str()) else {
                return Err(format!("group {i}: missing name"));
            };
            let Some(primary) = entry.get("primary").and_then(|p| p.as_str()) else {
                return Err(format!("group {name:?}: missing primary"));
            };
            let primary: SocketAddr = primary
                .parse()
                .map_err(|_| format!("group {name:?}: bad primary address {primary:?}"))?;
            let follower = match entry.get("follower").and_then(|f| f.as_str()) {
                Some(f) => Some(
                    f.parse()
                        .map_err(|_| format!("group {name:?}: bad follower address {f:?}"))?,
                ),
                None => None,
            };
            groups.push(GroupSpec { name: name.to_string(), primary, follower });
        }
        Self::new(seed, vnodes, groups)
    }

    pub fn to_json(&self) -> Json {
        let groups = self
            .groups
            .iter()
            .map(|g| {
                let mut fields = vec![
                    ("name", Json::str(&g.name)),
                    ("primary", Json::str(g.primary.to_string())),
                ];
                if let Some(f) = g.follower {
                    fields.push(("follower", Json::str(f.to_string())));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("vnodes", Json::num(self.vnodes as f64)),
            ("groups", Json::Arr(groups)),
        ])
    }

    /// The group index `task` is placed on: the owner of the first ring
    /// point at or after `fnv1a(task)`, wrapping past the top.
    pub fn group_for(&self, task: &str) -> usize {
        let h = fnv1a(task.as_bytes());
        let i = self.ring.partition_point(|&(point, _)| point < h);
        self.ring[i % self.ring.len()].1
    }

    /// Find a node identity (`"{group}/primary"` / `"{group}/follower"`)
    /// in the map: `(group index, is_follower)`.
    pub fn locate(&self, node_id: &str) -> Option<(usize, bool)> {
        let (name, role) = node_id.rsplit_once('/')?;
        let idx = self.groups.iter().position(|g| g.name == name)?;
        match role {
            "primary" => Some((idx, false)),
            "follower" if self.groups[idx].follower.is_some() => Some((idx, true)),
            _ => None,
        }
    }

    pub fn groups(&self) -> &[GroupSpec] {
        &self.groups
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn vnodes(&self) -> usize {
        self.vnodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn three_groups() -> Vec<GroupSpec> {
        (0..3)
            .map(|i| GroupSpec {
                name: format!("g{i}"),
                primary: addr(9000 + i),
                follower: Some(addr(9100 + i)),
            })
            .collect()
    }

    #[test]
    fn placement_is_deterministic_and_total() {
        let a = ClusterMap::new(7, 64, three_groups()).unwrap();
        let b = ClusterMap::new(7, 64, three_groups()).unwrap();
        for t in 0..500 {
            let task = format!("task-{t}");
            let g = a.group_for(&task);
            assert!(g < 3);
            assert_eq!(g, b.group_for(&task), "same map must place identically");
        }
        // A different seed produces a different ring (spot-check: at
        // least one of 500 tasks moves).
        let c = ClusterMap::new(8, 64, three_groups()).unwrap();
        assert!(
            (0..500).any(|t| {
                let task = format!("task-{t}");
                a.group_for(&task) != c.group_for(&task)
            }),
            "seed must perturb placement"
        );
    }

    #[test]
    fn virtual_nodes_balance_the_ring() {
        let map = ClusterMap::new(0, DEFAULT_VNODES, three_groups()).unwrap();
        let mut counts = [0usize; 3];
        for t in 0..1000 {
            counts[map.group_for(&format!("task-{t}"))] += 1;
        }
        // Expected share is ~333 with an arc-length σ of ~4 points at 64
        // vnodes; 120 (12%) is a >5σ floor — a failure here means the ring
        // construction broke, not that the dice came up cold.
        for (i, &n) in counts.iter().enumerate() {
            assert!(
                n >= 120,
                "group {i} got {n}/1000 tasks — ring badly imbalanced: {counts:?}"
            );
        }
    }

    #[test]
    fn wrap_around_covers_the_whole_hash_space() {
        // Tasks hashing past the last ring point must wrap to the first
        // group on the ring — exercised implicitly by totality above, and
        // explicitly here against a tiny ring where the wrap arc is large.
        let map = ClusterMap::new(0, 1, three_groups()).unwrap();
        for t in 0..2000 {
            let g = map.group_for(&format!("task-{t}"));
            assert!(g < 3);
        }
    }

    #[test]
    fn validation_rejects_bad_maps() {
        assert!(ClusterMap::new(0, 64, Vec::new()).is_err(), "empty groups");
        assert!(ClusterMap::new(0, 0, three_groups()).is_err(), "zero vnodes");
        let mut dup_name = three_groups();
        dup_name[2].name = "g0".into();
        assert!(ClusterMap::new(0, 64, dup_name).is_err(), "duplicate name");
        let mut slash = three_groups();
        slash[0].name = "g/0".into();
        assert!(ClusterMap::new(0, 64, slash).is_err(), "slash in name");
        let mut empty_name = three_groups();
        empty_name[1].name = String::new();
        assert!(ClusterMap::new(0, 64, empty_name).is_err(), "empty name");
        let mut dup_ep = three_groups();
        dup_ep[1].follower = Some(dup_ep[0].primary);
        assert!(ClusterMap::new(0, 64, dup_ep).is_err(), "duplicate endpoint");
    }

    #[test]
    fn json_roundtrip_preserves_placement() {
        let map = ClusterMap::new(7, 32, three_groups()).unwrap();
        let text = map.to_json().to_string();
        let back = ClusterMap::parse(&text).unwrap();
        assert_eq!(back.seed(), 7);
        assert_eq!(back.vnodes(), 32);
        assert_eq!(back.groups(), map.groups());
        for t in 0..200 {
            let task = format!("task-{t}");
            assert_eq!(map.group_for(&task), back.group_for(&task));
        }
    }

    #[test]
    fn parse_errors_name_the_offender() {
        assert!(ClusterMap::parse("{").is_err());
        assert!(ClusterMap::parse("{}").unwrap_err().contains("groups"));
        let missing_primary = r#"{"groups": [{"name": "g0"}]}"#;
        assert!(ClusterMap::parse(missing_primary).unwrap_err().contains("g0"));
        let bad_addr = r#"{"groups": [{"name": "g0", "primary": "nope"}]}"#;
        assert!(ClusterMap::parse(bad_addr).unwrap_err().contains("nope"));
    }

    #[test]
    fn locate_resolves_node_identities() {
        let map = ClusterMap::new(0, 64, three_groups()).unwrap();
        assert_eq!(map.locate("g1/primary"), Some((1, false)));
        assert_eq!(map.locate("g2/follower"), Some((2, true)));
        assert_eq!(map.locate("g9/primary"), None);
        assert_eq!(map.locate("g1/banana"), None);
        assert_eq!(map.locate("no-slash"), None);
        // A follower id on a group without a follower does not resolve.
        let mut no_follower = three_groups();
        no_follower[0].follower = None;
        let map = ClusterMap::new(0, 64, no_follower).unwrap();
        assert_eq!(map.locate("g0/follower"), None);
        assert_eq!(map.locate("g0/primary"), Some((0, false)));
    }

    #[test]
    fn node_ids_derive_from_group_names() {
        let g = &three_groups()[1];
        assert_eq!(g.primary_id(), "g1/primary");
        assert_eq!(g.follower_id(), "g1/follower");
    }
}
