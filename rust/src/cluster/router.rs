//! The client-side cluster router: one [`RemoteBinding`] per replication
//! group, every call routed by its task through the consistent-hash ring.
//!
//! [`ClusterRouter`] implements the same [`CacheBackend`] /
//! [`SessionBackend`] traits as a single binding, so executors, sessions,
//! and the training drivers are agnostic to whether they talk to one
//! process or a fleet. The cluster properties all fall out of *which*
//! binding a call lands on:
//!
//! * **Sticky sessions** — a task's every call hashes to the same group,
//!   so its cursors, resume pins, and snapshots live on exactly one
//!   primary (and its warm follower).
//! * **Independent failover** — each group's binding owns its own breaker,
//!   endpoints, and epoch fence. A dead primary fails over to *its*
//!   follower ([`crate::client::BindingConfig::endpoints`]); the other
//!   groups never notice. The per-task trait methods
//!   ([`SessionBackend::generation_for`], [`CacheBackend::degraded_for`])
//!   keep the blast radius per-group: only sessions placed on the failed
//!   group re-seed or bypass.
//! * **Per-group epoch fencing** — epochs are a property of one group's
//!   promotion history; the router never compares epochs across groups.
//!
//! The router can also *assert* placement: [`ClusterRouter::check_identity`]
//! runs the extended `/capabilities` hello against every group's active
//! endpoint and verifies the node reports the identity the map derives
//! ([`GroupSpec::primary_id`] / `follower_id`), and
//! [`ClusterRouter::cluster_stats`] fans `GET /stats` in from every group
//! into one merged + per-group view (the `/cluster_stats` surface).

use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::cache::{
    BackendStats, CacheBackend, CacheStats, Capabilities, CursorStep, Lookup, NodeId,
    SessionBackend, SnapshotCosts, ToolCall, ToolResult, TurnBatch, TurnReply,
};
use crate::client::{BindingConfig, RemoteBinding};
use crate::cluster::ring::{ClusterMap, GroupSpec};
use crate::sandbox::SandboxSnapshot;
use crate::util::http::HttpClient;
use crate::util::json::{self, Json};
use crate::wire;

/// Client-side router over a [`ClusterMap`]: one binding per group.
pub struct ClusterRouter {
    map: ClusterMap,
    /// Indexed like `map.groups()`.
    bindings: Vec<RemoteBinding>,
    cfg: BindingConfig,
    /// Definitive node-identity mismatches observed by
    /// [`ClusterRouter::check_identity`].
    identity_mismatches: AtomicU64,
}

impl ClusterRouter {
    /// Connect one [`RemoteBinding`] per group. `cfg` applies to every
    /// group; each group's endpoint list is its own primary + follower
    /// (whatever `cfg.endpoints` held is ignored — the map is
    /// authoritative), and each binding gets a distinct jitter seed so
    /// concurrent groups do not back off in lockstep.
    pub fn connect(map: ClusterMap, cfg: BindingConfig) -> ClusterRouter {
        let bindings = map
            .groups()
            .iter()
            .enumerate()
            .map(|(i, g)| {
                let group_cfg = BindingConfig {
                    endpoints: g.follower.into_iter().collect(),
                    seed: cfg.seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    ..cfg.clone()
                };
                RemoteBinding::connect_with(g.primary, group_cfg)
            })
            .collect();
        ClusterRouter { map, bindings, cfg, identity_mismatches: AtomicU64::new(0) }
    }

    pub fn map(&self) -> &ClusterMap {
        &self.map
    }

    /// The group index `task` is placed on (tests, diagnostics).
    pub fn group_of(&self, task: &str) -> usize {
        self.map.group_for(task)
    }

    /// White-box access to one group's binding (tests, diagnostics).
    pub fn binding(&self, group: usize) -> &RemoteBinding {
        &self.bindings[group]
    }

    /// Definitive identity mismatches seen so far.
    pub fn identity_mismatches(&self) -> u64 {
        self.identity_mismatches.load(Ordering::Relaxed)
    }

    fn route(&self, task: &str) -> &RemoteBinding {
        &self.bindings[self.map.group_for(task)]
    }

    /// The node identity the map expects at `addr` within group `g`.
    fn expected_id(g: &GroupSpec, addr: SocketAddr) -> String {
        if g.follower == Some(addr) {
            g.follower_id()
        } else {
            g.primary_id()
        }
    }

    /// Assert every group's *active* endpoint is the node the map says it
    /// is, via the extended `/capabilities` hello. Returns `false` — and
    /// counts an identity mismatch — on any definitive mismatch: the node
    /// reported a different identity, or answered
    /// `421 Misdirected Request` to the expectation. Nodes that answer
    /// with the plain frame, report no identity, 404 the endpoint, or are
    /// unreachable cannot be *dis*proven and pass — identity checking is
    /// a misconfiguration tripwire, not a liveness probe.
    pub fn check_identity(&self) -> bool {
        let mut ok = true;
        for (g, binding) in self.map.groups().iter().zip(&self.bindings) {
            let addr = binding.active_endpoint();
            let expect = Self::expected_id(g, addr);
            let mut buf = Vec::with_capacity(32);
            wire::enc_hello_ext(&mut buf, Capabilities::PROTO_V2, &expect);
            let mut probe =
                HttpClient::with_deadlines(addr, self.cfg.connect_timeout, self.cfg.read_timeout);
            match probe.post("/capabilities", &buf) {
                Ok((200, body)) => {
                    if let Some((_, _, Some(actual))) = wire::dec_caps_resp_ext(&body) {
                        if !actual.is_empty() && actual != expect {
                            self.identity_mismatches.fetch_add(1, Ordering::Relaxed);
                            ok = false;
                        }
                    }
                }
                Ok((421, _)) => {
                    self.identity_mismatches.fetch_add(1, Ordering::Relaxed);
                    ok = false;
                }
                Ok(_) | Err(_) => {}
            }
        }
        ok
    }

    /// Fan `GET /stats` in from every group: the `/cluster_stats` surface.
    /// The merged half sums counters across groups (and ORs the sticky
    /// degradation flags); the per-group half carries what cannot be
    /// meaningfully merged — role, epoch, and lag are properties of one
    /// group's replication line.
    pub fn cluster_stats(&self) -> ClusterStats {
        let mut groups = Vec::with_capacity(self.bindings.len());
        for (g, binding) in self.map.groups().iter().zip(&self.bindings) {
            let addr = binding.active_endpoint();
            let mut probe =
                HttpClient::with_deadlines(addr, self.cfg.connect_timeout, self.cfg.read_timeout);
            let doc = match probe.get("/stats") {
                Ok((200, body)) => {
                    std::str::from_utf8(&body).ok().and_then(|s| json::parse(s).ok())
                }
                _ => None,
            };
            let stats = doc
                .as_ref()
                .and_then(BackendStats::from_json)
                .unwrap_or_default();
            let str_field = |key: &str| {
                doc.as_ref()
                    .and_then(|d| d.get(key).and_then(|v| v.as_str()).map(str::to_string))
            };
            groups.push(GroupStatus {
                name: g.name.clone(),
                endpoint: addr,
                reachable: doc.is_some(),
                role: str_field("role").unwrap_or_else(|| "unreachable".into()),
                node_id: str_field("node_id").unwrap_or_default(),
                epoch: stats.epoch,
                replica_lag_ops: stats.replica_lag_ops,
                failovers: binding.failovers(),
                breaker: binding.breaker_state(),
            });
        }
        ClusterStats { merged: self.service_stats(), groups }
    }
}

/// Per-group status in a [`ClusterStats`] report.
#[derive(Debug, Clone)]
pub struct GroupStatus {
    pub name: String,
    /// The endpoint the group's binding currently routes to (the follower
    /// after a failover).
    pub endpoint: SocketAddr,
    /// Whether `GET /stats` answered; the fields below are zeros/empty
    /// when it did not.
    pub reachable: bool,
    /// `"primary"` / `"follower"` as the node reports it, or
    /// `"unreachable"`.
    pub role: String,
    /// The node's configured identity (empty when it has none).
    pub node_id: String,
    /// The group's fencing epoch.
    pub epoch: u64,
    /// The group's replication lag in ops.
    pub replica_lag_ops: u64,
    /// Failovers this router's binding performed within the group.
    pub failovers: u64,
    /// The group binding's breaker state.
    pub breaker: &'static str,
}

/// The `/cluster_stats` fan-in: merged service stats plus one
/// [`GroupStatus`] per group.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    pub merged: BackendStats,
    pub groups: Vec<GroupStatus>,
}

impl ClusterStats {
    pub fn to_json(&self) -> Json {
        let groups = self
            .groups
            .iter()
            .map(|g| {
                Json::obj(vec![
                    ("name", Json::str(&g.name)),
                    ("endpoint", Json::str(g.endpoint.to_string())),
                    ("reachable", Json::Bool(g.reachable)),
                    ("role", Json::str(&g.role)),
                    ("node_id", Json::str(&g.node_id)),
                    ("epoch", Json::num(g.epoch as f64)),
                    ("replica_lag_ops", Json::num(g.replica_lag_ops as f64)),
                    ("failovers", Json::num(g.failovers as f64)),
                    ("breaker", Json::str(g.breaker)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("merged", self.merged.to_json()),
            ("groups", Json::Arr(groups)),
        ])
    }
}

/// Sum `b` into `a` field-by-field. Counters add; sticky degradation
/// flags OR; `epoch` takes the max (epochs are per-group and incomparable
/// across groups — the max is "the deepest promotion history anywhere").
fn merge_stats(a: &mut BackendStats, b: &BackendStats) {
    a.shards += b.shards;
    a.tasks += b.tasks;
    a.lookups += b.lookups;
    a.hits += b.hits;
    a.snapshots += b.snapshots;
    a.snapshot_bytes += b.snapshot_bytes;
    a.spilled_snapshots += b.spilled_snapshots;
    a.spilled_bytes += b.spilled_bytes;
    a.spills += b.spills;
    a.spill_faults += b.spill_faults;
    a.bg_evictions += b.bg_evictions;
    a.dedup_hits += b.dedup_hits;
    a.dedup_resident_bytes_saved += b.dedup_resident_bytes_saved;
    a.fault_cache_hits += b.fault_cache_hits;
    a.fault_cache_misses += b.fault_cache_misses;
    a.fault_cache_evictions += b.fault_cache_evictions;
    a.remote_retries += b.remote_retries;
    a.breaker_opens += b.breaker_opens;
    a.breaker_half_opens += b.breaker_half_opens;
    a.breaker_closes += b.breaker_closes;
    a.spill_degraded |= b.spill_degraded;
    a.injected_faults += b.injected_faults;
    a.failovers += b.failovers;
    a.epoch_rejects += b.epoch_rejects;
    a.replica_lag_ops += b.replica_lag_ops;
    a.epoch = a.epoch.max(b.epoch);
    a.oplog_appended += b.oplog_appended;
    a.replicate_bytes_shipped += b.replicate_bytes_shipped;
    a.wal_segments += b.wal_segments;
    a.wal_fsyncs += b.wal_fsyncs;
    a.wal_appended_bytes += b.wal_appended_bytes;
    a.wal_degraded |= b.wal_degraded;
    a.recoveries += b.recoveries;
}

impl CacheBackend for ClusterRouter {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        self.route(task).lookup(task, q)
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> Option<NodeId> {
        self.route(task).insert(task, traj)
    }

    fn release(&self, task: &str, node: NodeId) {
        self.route(task).release(task, node)
    }

    fn should_snapshot(&self, task: &str, costs: SnapshotCosts) -> bool {
        self.route(task).should_snapshot(task, costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        self.route(task).store_snapshot(task, node, snap)
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        self.route(task).fetch_snapshot(task, id)
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        self.route(task).set_warm_fork(task, node, warm)
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.route(task).has_warm_fork(task, node)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.route(task).stats(task)
    }

    fn service_stats(&self) -> BackendStats {
        let mut merged = BackendStats::default();
        for b in &self.bindings {
            merge_stats(&mut merged, &b.service_stats());
        }
        merged
    }

    /// Persist fans out: each group persists to its own `{dir}/{name}`
    /// subdirectory (server-local paths — with in-process groups sharing
    /// one filesystem, a shared `dir` would collide). `true` only when
    /// every group persisted.
    fn persist(&self, dir: &str) -> bool {
        self.map
            .groups()
            .iter()
            .zip(&self.bindings)
            .all(|(g, b)| b.persist(&format!("{dir}/{}", g.name)))
    }

    fn warm_start(&self, dir: &str) -> bool {
        self.map
            .groups()
            .iter()
            .zip(&self.bindings)
            .all(|(g, b)| b.warm_start(&format!("{dir}/{}", g.name)))
    }

    /// The whole router is degraded only when *every* group is — per-task
    /// callers use [`CacheBackend::degraded_for`], which answers for the
    /// one group the task lives on.
    fn degraded(&self) -> bool {
        self.bindings.iter().all(|b| b.degraded())
    }

    fn degraded_for(&self, task: &str) -> bool {
        self.route(task).degraded()
    }
}

impl SessionBackend for ClusterRouter {
    /// The cluster-wide *intersection*: a capability is advertised only
    /// when every group speaks it (callers that cannot route by task must
    /// be safe on every group). Per-task callers use
    /// [`SessionBackend::capabilities_for`].
    fn capabilities(&self) -> Capabilities {
        let mut all = Capabilities::V2;
        for b in &self.bindings {
            let c = b.capabilities();
            all.binary &= c.binary;
            all.cursors &= c.cursors;
            all.turn_batch &= c.turn_batch;
            all.payload_dedup &= c.payload_dedup;
        }
        all
    }

    fn capabilities_for(&self, task: &str) -> Capabilities {
        self.route(task).capabilities()
    }

    /// The sum of every group's generation: bumps whenever *any* group
    /// fails over. Sessions use [`SessionBackend::generation_for`], which
    /// only moves when the task's own group does.
    fn backend_generation(&self) -> u64 {
        self.bindings.iter().map(|b| b.backend_generation()).sum()
    }

    fn generation_for(&self, task: &str) -> u64 {
        self.route(task).backend_generation()
    }

    fn cursor_open(&self, task: &str) -> u64 {
        self.route(task).cursor_open(task)
    }

    fn cursor_step(&self, task: &str, cursor: u64, call: &ToolCall) -> CursorStep {
        self.route(task).cursor_step(task, cursor, call)
    }

    fn cursor_record(
        &self,
        task: &str,
        cursor: u64,
        call: &ToolCall,
        result: &ToolResult,
    ) -> Option<NodeId> {
        self.route(task).cursor_record(task, cursor, call, result)
    }

    fn cursor_seek(&self, task: &str, cursor: u64, node: NodeId, steps: usize) -> bool {
        self.route(task).cursor_seek(task, cursor, node, steps)
    }

    fn cursor_close(&self, task: &str, cursor: u64) {
        self.route(task).cursor_close(task, cursor)
    }

    fn session_release(&self, task: &str, cursor: u64, node: NodeId) {
        self.route(task).session_release(task, cursor, node)
    }

    fn session_turn(&self, task: &str, cursor: u64, batch: &TurnBatch) -> TurnReply {
        self.route(task).session_turn(task, cursor, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_sticky_and_total() {
        let groups = (0..3)
            .map(|i| GroupSpec {
                name: format!("g{i}"),
                primary: format!("127.0.0.1:{}", 9200 + i).parse().unwrap(),
                follower: None,
            })
            .collect();
        let map = ClusterMap::new(3, 16, groups).unwrap();
        let router = ClusterRouter::connect(map, BindingConfig::default());
        for t in 0..200 {
            let task = format!("task-{t}");
            let g = router.group_of(&task);
            assert!(g < 3);
            assert_eq!(g, router.group_of(&task));
            assert_eq!(
                router.binding(g).active_endpoint(),
                router.map().groups()[g].primary
            );
        }
    }

    #[test]
    fn expected_identity_follows_the_active_endpoint() {
        let g = GroupSpec {
            name: "g0".into(),
            primary: "127.0.0.1:9300".parse().unwrap(),
            follower: Some("127.0.0.1:9301".parse().unwrap()),
        };
        assert_eq!(ClusterRouter::expected_id(&g, g.primary), "g0/primary");
        assert_eq!(
            ClusterRouter::expected_id(&g, g.follower.unwrap()),
            "g0/follower"
        );
    }
}
