//! The cluster layer: consistent-hash task placement across several
//! replicated TVCACHE processes.
//!
//! A single process caps how many concurrent tasks one cache can serve;
//! at fleet scale hundreds of rollout workers hammer the cache at once.
//! This module scales the existing single-group machinery *out* without
//! changing any of it:
//!
//! * [`ring`] — a static-membership cluster map ([`ClusterMap`], parsed
//!   from `cluster.json`) built on a seeded consistent-hash ring with
//!   virtual nodes. It places every `task_id` on exactly one
//!   **replication group**: one primary plus an optional warm follower,
//!   each launched with today's `tvcache serve` / `--follow` and wired
//!   together by the PR 8/9 op-log, `/promote`, and `/bootstrap`
//!   machinery — the cluster layer reuses all of it verbatim.
//! * [`router`] — the client side: [`ClusterRouter`] implements
//!   [`crate::cache::CacheBackend`] / [`crate::cache::SessionBackend`] by
//!   owning one [`crate::client::RemoteBinding`] per group and routing
//!   every call by its task. Sessions are sticky to their group; a
//!   breaker-open failover promotes *that group's* follower without
//!   disturbing the others; epoch fencing stays per-group.
//!
//! Placement is enforced at both ends: the router only sends a task where
//! the ring points, and a map-armed server
//! ([`crate::server::CacheService::set_cluster_guard`]) answers
//! `421 Misdirected Request` to any task the ring places elsewhere, so a
//! stale or misconfigured router can never silently populate the wrong
//! node's cache. The extended `/capabilities` hello carries the node
//! identity ([`crate::wire::enc_hello_ext`]) so the router can also assert
//! it reached the node the ring chose.

pub mod ring;
pub mod router;

pub use ring::{ClusterMap, GroupSpec, DEFAULT_VNODES};
pub use router::{ClusterRouter, ClusterStats, GroupStatus};
