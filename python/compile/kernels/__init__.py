"""Layer-1 Pallas kernels + pure-jnp oracles."""
from . import ref  # noqa: F401
from .attention import causal_attention  # noqa: F401
from .rmsnorm import rmsnorm  # noqa: F401
