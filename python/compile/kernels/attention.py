"""Layer-1 Pallas kernel: fused causal attention.

The paper's hot compute path during post-training is the agent policy's
forward/backward; within it, attention dominates. This kernel fuses
QKᵀ → causal mask → streaming softmax → ·V for one (batch, head) program
instance, tiling the key/value sequence axis so only O(block) of K/V is
resident at once.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over
(batch·head, query-block); each program keeps a q-block of shape
``[BLOCK_Q, D]`` resident in VMEM and streams k/v blocks of shape
``[BLOCK_K, D]`` through VMEM, accumulating with the usual online-softmax
(m, l, acc) recurrence — the Pallas analogue of what FlashAttention does
with CUDA shared memory. Matmuls are shaped [BLOCK_Q, D] × [D, BLOCK_K]
and [BLOCK_Q, BLOCK_K] × [BLOCK_K, D]: MXU-systolic-friendly.

``interpret=True`` is mandatory on this CPU-PJRT toolchain — real TPU
lowering emits a Mosaic custom-call the CPU plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

# Default tile sizes. For the small policy models in this repro the whole
# sequence usually fits one tile; the streaming structure still exercises the
# multi-block path in tests (see test_kernels.py with T > BLOCK).
BLOCK_Q = 64
BLOCK_K = 64


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_len: int):
    """One program instance: all query rows of one (b, h) q-block."""
    q = q_ref[...]  # [bq, d]
    bq, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    q_block_idx = pl.program_id(1)
    q_offset = q_block_idx * bq  # global row index of q row 0

    n_kblocks = pl.cdiv(seq_len, block_k)

    def body(kb, carry):
        m_prev, l_prev, acc_prev = carry
        k_blk = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        # Rows past seq_len are out-of-bounds padding (NaN under interpret
        # mode); zero them so `0 * pad` cannot poison the accumulator.
        k_valid = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0
        ) < seq_len
        k_blk = jnp.where(k_valid, k_blk, 0)
        v_blk = jnp.where(k_valid, v_blk, 0)
        s = jnp.dot(q.astype(jnp.float32), k_blk.astype(jnp.float32).T) * scale

        # Causal + padding mask in global coordinates.
        q_ids = q_offset + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        k_ids = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        mask = (k_ids <= q_ids) & (k_ids < seq_len)
        s = jnp.where(mask, s, NEG_INF)

        # Online softmax recurrence.
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(p, v_blk.astype(jnp.float32))
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kblocks, body, (m0, l0, acc0))

    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _attention_fwd_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
) -> jnp.ndarray:
    """Fused causal attention forward, Pallas implementation.

    Shapes as in :func:`compile.kernels.ref.causal_attention`:
    ``q, k, v: [B, H, T, D] -> [B, H, T, D]``.
    """
    b, h, t, d = q.shape
    bq = min(block_q, t)
    bk = min(block_k, t)

    qf = q.reshape(b * h, t, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)

    grid = (b * h, pl.cdiv(t, bq))
    kernel = functools.partial(_attn_kernel, block_k=bk, seq_len=t)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, t, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, t, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qf, kf, vf)
    return out.reshape(b, h, t, d)


# --------------------------------------------------------------------------
# Autodiff: interpret-mode pallas_call has no VJP rule, so we attach the
# analytic attention backward (standard FlashAttention-style math, computed
# in plain jnp). The forward stays on the Pallas kernel, so the AOT train
# graph still exercises the fused kernel.
# --------------------------------------------------------------------------


@jax.custom_vjp
def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Fused causal attention with analytic VJP. ``[B,H,T,D] -> [B,H,T,D]``."""
    return _attention_fwd_pallas(q, k, v)


def _attn_vjp_fwd(q, k, v):
    return _attention_fwd_pallas(q, k, v), (q, k, v)


def _attn_vjp_bwd(res, do):
    q, k, v = res
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    t = q.shape[-2]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))[None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bhts,bhtd->bhsd", p, do)
    dp = jnp.einsum("bhtd,bhsd->bhts", do, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhts,bhsd->bhtd", ds, k) * scale
    dk = jnp.einsum("bhts,bhtd->bhsd", ds, q) * scale
    return dq, dk, dv


causal_attention.defvjp(_attn_vjp_fwd, _attn_vjp_bwd)
