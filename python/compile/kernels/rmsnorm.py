"""Layer-1 Pallas kernel: RMSNorm over the feature axis.

One program instance normalizes a block of rows; the feature axis stays
resident (policy-model widths are well under VMEM capacity). Accumulation is
in f32 regardless of input dtype, matching the oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [rows, d]
    g = g_ref[...].astype(jnp.float32)  # [d]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g[None, :]).astype(o_ref.dtype)


def _rmsnorm_fwd_pallas(
    x: jnp.ndarray,
    gamma: jnp.ndarray,
    eps: float = 1e-6,
    *,
    block_rows: int = BLOCK_ROWS,
) -> jnp.ndarray:
    """RMSNorm forward: ``x * gamma / rms(x)`` over the last axis.

    ``x: [..., D]``, ``gamma: [D]``; leading axes are flattened into rows.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = int(x.size // d)
    xf = x.reshape(rows, d)
    br = min(block_rows, rows)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pl.cdiv(rows, br),),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(xf, gamma)
    return out.reshape(orig_shape)


# Analytic VJP (interpret-mode pallas_call has no autodiff rule); the
# forward stays on the Pallas kernel inside the AOT train graph.
#
#   r = (mean(x^2) + eps)^-1/2 ;  y = x * g * r
#   dx = g*r*dy - x * r^3 / D * sum_d(dy * g * x)
#   dg = sum_rows(dy * x * r)

_EPS = 1e-6


@jax.custom_vjp
def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm (eps fixed at 1e-6) with analytic VJP. ``x: [..., D]``."""
    return _rmsnorm_fwd_pallas(x, gamma, _EPS)


def _rms_vjp_fwd(x, gamma):
    return _rmsnorm_fwd_pallas(x, gamma, _EPS), (x, gamma)


def _rms_vjp_bwd(res, dy):
    x, gamma = res
    eps = _EPS
    d = x.shape[-1]
    r = jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    inner = jnp.sum(dy * gamma * x, axis=-1, keepdims=True)
    dx = gamma * r * dy - x * (r**3) * inner / d
    dg = jnp.sum((dy * x * r).reshape(-1, d), axis=0)
    return dx, dg


rmsnorm.defvjp(_rms_vjp_fwd, _rms_vjp_bwd)
