"""Pure-jnp reference oracles for the Layer-1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle here to float tolerance across the shape/dtype sweep in
``python/tests/test_kernels.py`` (pytest + hypothesis). The oracles are also
what the Layer-2 model uses when ``use_pallas=False``, so a single flag flips
the whole AOT pipeline between kernel and reference numerics.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30  # finite "minus infinity" — keeps softmax NaN-free on fully
# masked rows (padding positions) in both the oracle and the kernel.


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Causal scaled-dot-product attention.

    Shapes: q, k, v are ``[B, H, T, D]``; returns ``[B, H, T, D]``.
    Row ``t`` attends to keys ``0..t`` (inclusive).
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    t = q.shape[-2]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None, :, :], scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", probs, v)


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm over the last axis: ``x * gamma / rms(x)``.

    ``x``: ``[..., D]``, ``gamma``: ``[D]``.
    """
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * gamma
