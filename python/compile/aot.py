"""AOT entry point: lower the Layer-2 graphs to HLO text artifacts.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits, for the configured model size:

    agent_init.hlo.txt    (seed i32[1]) -> (params f32[P],)
    agent_fwd.hlo.txt     (params, tokens i32[B,T], lens i32[B]) -> (logits f32[B,V],)
    agent_train.hlo.txt   (params, m, v, step f32[1], tokens i32[BT,T],
                           mask f32[BT,T], adv f32[BT])
                          -> (params', m', v', loss f32[1])
    meta.json             param_count + config, read by the Rust runtime

HLO *text* is the interchange format: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import ModelConfig


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(cfg: ModelConfig, rollout_batch: int, train_batch: int):
    """Return {artifact_name: hlo_text} for the three graphs."""
    p = model.param_count(cfg)
    f32, i32 = jnp.float32, jnp.int32
    spec = jax.ShapeDtypeStruct

    init_fn = functools.partial(model.init_params, cfg)
    init = jax.jit(lambda seed: (init_fn(seed),)).lower(spec((1,), i32))

    fwd_fn = functools.partial(model.forward, cfg)
    fwd = jax.jit(lambda fl, tok, ln: (fwd_fn(fl, tok, ln),)).lower(
        spec((p,), f32),
        spec((rollout_batch, cfg.seq), i32),
        spec((rollout_batch,), i32),
    )

    train_fn = functools.partial(model.train_step, cfg)
    train = jax.jit(train_fn).lower(
        spec((p,), f32),
        spec((p,), f32),
        spec((p,), f32),
        spec((1,), f32),
        spec((train_batch, cfg.seq), i32),
        spec((train_batch, cfg.seq), f32),
        spec((train_batch,), f32),
    )

    return {
        "agent_init": to_hlo_text(init),
        "agent_fwd": to_hlo_text(fwd),
        "agent_train": to_hlo_text(train),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--entropy-coef", type=float, default=0.01)
    ap.add_argument("--rollout-batch", type=int, default=8,
                    help="B for agent_fwd (= rollouts sampled in lockstep)")
    ap.add_argument("--train-batch", type=int, default=32,
                    help="B for agent_train (= rollouts per update)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower with the pure-jnp reference kernels instead")
    args = ap.parse_args()

    cfg = ModelConfig(
        vocab=args.vocab, seq=args.seq, d_model=args.d_model,
        n_layers=args.n_layers, n_heads=args.n_heads, d_ff=args.d_ff,
        lr=args.lr, entropy_coef=args.entropy_coef,
        use_pallas=not args.no_pallas,
    )

    os.makedirs(args.out_dir, exist_ok=True)
    arts = lower_all(cfg, args.rollout_batch, args.train_batch)
    for name, text in arts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "param_count": model.param_count(cfg),
        "vocab": cfg.vocab, "seq": cfg.seq, "d_model": cfg.d_model,
        "n_layers": cfg.n_layers, "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
        "lr": cfg.lr, "entropy_coef": cfg.entropy_coef,
        "rollout_batch": args.rollout_batch, "train_batch": args.train_batch,
        "use_pallas": cfg.use_pallas,
    }
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"param_count = {meta['param_count']}")


if __name__ == "__main__":
    main()
