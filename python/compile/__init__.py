"""Build-time-only package: Layer-1 Pallas kernels + Layer-2 JAX model + AOT."""
