"""Layer-2: the agent policy network and its training step, in JAX.

The policy is a small causal transformer over a *tool-action vocabulary*:
each token is one tool invocation (tool id × argument bucket), so a rollout's
tool-call trajectory is exactly a token sequence. The Rust Layer-3 samples
actions from `forward` logits during rollouts and applies `train_step`
(GRPO/REINFORCE with Adam) after each batch of rewarded rollouts.

Interface contract with Rust (see rust/src/runtime/):

* Parameters are a single flat ``f32[P]`` vector. Packing order is defined
  by :func:`param_layout`; Rust never needs to know it — it only threads the
  vector between ``init → forward → train_step``.
* ``forward(params, tokens i32[B,T], lens i32[B]) -> logits f32[B,V]`` —
  next-action logits at position ``lens-1`` (tokens beyond ``lens`` are
  padding and are masked out of attention by causality + the gather).
* ``train_step(params, m, v, step f32[1], tokens i32[B,T], mask f32[B,T],
  adv f32[B]) -> (params', m', v', loss f32[1])`` — one Adam step on the
  policy-gradient loss ``-Σ mask·adv·log p(token[t+1] | tokens[:t+1])``.
  With ``adv = 1`` this is exactly the LM cross-entropy step, which is how
  ``examples/pretrain_lm.rs`` reuses the same artifact family.

All graphs are lowered once by ``aot.py``; Python never runs at post-training
time.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + optimizer hyper-parameters (baked at AOT time)."""

    vocab: int = 64
    seq: int = 48
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8
    entropy_coef: float = 0.01
    use_pallas: bool = True

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# --------------------------------------------------------------------------
# Flat parameter packing
# --------------------------------------------------------------------------

def param_layout(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat-vector layout."""
    layout: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        layout += [
            (f"l{i}.ln1", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
        ]
    layout += [
        ("ln_f", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
    ]
    return layout


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_layout(cfg))


def unpack(cfg: ModelConfig, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Slice the flat vector into named tensors (traced, zero-copy views)."""
    out: Dict[str, jnp.ndarray] = {}
    off = 0
    for name, shape in param_layout(cfg):
        n = 1
        for s in shape:
            n *= s
        out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
        off += n
    return out


def pack(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_layout(cfg)]
    )


def init_params(cfg: ModelConfig, seed: jnp.ndarray) -> jnp.ndarray:
    """Random init (scaled-normal weights, ones for norms) as a flat vector."""
    key = jax.random.PRNGKey(seed[0].astype(jnp.uint32))
    parts = []
    for i, (name, shape) in enumerate(param_layout(cfg)):
        k = jax.random.fold_in(key, i)
        if name.endswith(("ln1", "ln2", "ln_f")):
            p = jnp.ones(shape, jnp.float32)
        elif name == "pos":
            p = 0.01 * jax.random.normal(k, shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            p = jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(
                jnp.asarray(fan_in, jnp.float32)
            )
        parts.append(p.reshape(-1))
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _attention(cfg: ModelConfig, x: jnp.ndarray, p: Dict[str, jnp.ndarray], i: int):
    """Multi-head causal self-attention for layer ``i``. x: [B, T, D]."""
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def split(w):
        return (x @ w).reshape(b, t, h, dh).transpose(0, 2, 1, 3)  # [B,H,T,dh]

    q, k, v = split(p[f"l{i}.wq"]), split(p[f"l{i}.wk"]), split(p[f"l{i}.wv"])
    attn = kernels.causal_attention if cfg.use_pallas else kref.causal_attention
    o = attn(q, k, v)  # [B,H,T,dh]
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    return o @ p[f"l{i}.wo"]


def _norm(cfg: ModelConfig, x: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    norm = kernels.rmsnorm if cfg.use_pallas else kref.rmsnorm
    return norm(x, gamma)


def logits_all(cfg: ModelConfig, flat: jnp.ndarray, tokens: jnp.ndarray):
    """Full-sequence logits ``[B, T, V]`` (shared by forward + train)."""
    p = unpack(cfg, flat)
    b, t = tokens.shape
    x = p["embed"][tokens] + p["pos"][None, :t, :]
    for i in range(cfg.n_layers):
        x = x + _attention(cfg, _norm(cfg, x, p[f"l{i}.ln1"]), p, i)
        hdn = _norm(cfg, x, p[f"l{i}.ln2"])
        x = x + jax.nn.gelu(hdn @ p[f"l{i}.w1"]) @ p[f"l{i}.w2"]
    x = _norm(cfg, x, p["ln_f"])
    return x @ p["head"]


def forward(cfg: ModelConfig, flat: jnp.ndarray, tokens: jnp.ndarray, lens: jnp.ndarray):
    """Next-action logits at position ``lens - 1``: ``[B, V]``."""
    lg = logits_all(cfg, flat, tokens)  # [B, T, V]
    idx = jnp.clip(lens - 1, 0, cfg.seq - 1)
    return jnp.take_along_axis(lg, idx[:, None, None], axis=1)[:, 0, :]


# --------------------------------------------------------------------------
# Training step (GRPO / REINFORCE with Adam)
# --------------------------------------------------------------------------

def pg_loss(cfg, flat, tokens, mask, adv):
    """Masked, advantage-weighted negative log-likelihood (+ entropy bonus).

    ``tokens[b, t+1]`` is the action sampled after observing ``tokens[b, :t+1]``;
    ``mask[b, t]`` gates whether position ``t``'s prediction participates.
    """
    lg = logits_all(cfg, flat, tokens)  # [B, T, V]
    logp = jax.nn.log_softmax(lg[:, :-1, :], axis=-1)  # predicts tokens[:,1:]
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[:, :, None], axis=-1)[:, :, 0]  # [B,T-1]
    m = mask[:, : cfg.seq - 1]
    denom = jnp.maximum(m.sum(), 1.0)
    pg = (nll * m * adv[:, None]).sum() / denom
    probs = jnp.exp(logp)
    entropy = (-(probs * logp).sum(-1) * m).sum() / denom
    return pg - cfg.entropy_coef * entropy


def train_step(cfg, flat, m_state, v_state, step, tokens, mask, adv):
    """One Adam step on :func:`pg_loss`. Returns (params', m', v', loss[1])."""
    loss, grads = jax.value_and_grad(pg_loss, argnums=1)(cfg, flat, tokens, mask, adv)
    t = step[0]
    m_new = cfg.beta1 * m_state + (1 - cfg.beta1) * grads
    v_new = cfg.beta2 * v_state + (1 - cfg.beta2) * jnp.square(grads)
    m_hat = m_new / (1 - cfg.beta1 ** t)
    v_hat = v_new / (1 - cfg.beta2 ** t)
    flat_new = flat - cfg.lr * m_hat / (jnp.sqrt(v_hat) + cfg.adam_eps)
    return flat_new, m_new, v_new, loss.reshape(1)
