"""L1 correctness: Pallas kernels vs pure-jnp oracles (pytest + hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

ATOL = 2e-5
RTOL = 2e-5


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Causal attention
# ---------------------------------------------------------------------------

SHAPES = [
    (1, 1, 1, 8),     # degenerate single position
    (1, 1, 8, 16),    # single block
    (2, 3, 64, 32),   # exactly one block boundary
    (2, 3, 65, 32),   # straddles the block boundary (padding path)
    (1, 2, 128, 16),  # two full blocks
    (1, 1, 130, 8),   # two blocks + remainder
]


@pytest.mark.parametrize("shape", SHAPES)
def test_attention_matches_ref(shape):
    b, h, t, d = shape
    key = jax.random.PRNGKey(hash(shape) % (2**31))
    q, k, v = (_rand(jax.random.fold_in(key, i), shape, jnp.float32) for i in range(3))
    got = kernels.causal_attention(q, k, v)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_attention_is_causal():
    """Perturbing future keys/values must not change earlier outputs."""
    key = jax.random.PRNGKey(7)
    b, h, t, d = 1, 2, 32, 16
    q, k, v = (_rand(jax.random.fold_in(key, i), (b, h, t, d), jnp.float32) for i in range(3))
    base = kernels.causal_attention(q, k, v)
    k2 = k.at[:, :, t // 2 :, :].set(99.0)
    v2 = v.at[:, :, t // 2 :, :].set(-99.0)
    pert = kernels.causal_attention(q, k2, v2)
    np.testing.assert_allclose(base[:, :, : t // 2], pert[:, :, : t // 2], rtol=RTOL, atol=ATOL)
    assert not np.allclose(base[:, :, t // 2 :], pert[:, :, t // 2 :], atol=1e-3)


def test_attention_first_row_is_v0():
    """Row 0 attends only to key 0, so output row 0 == v row 0."""
    key = jax.random.PRNGKey(3)
    q, k, v = (_rand(jax.random.fold_in(key, i), (1, 1, 16, 8), jnp.float32) for i in range(3))
    out = kernels.causal_attention(q, k, v)
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=RTOL, atol=ATOL)


def test_attention_grad_matches_ref():
    """custom_vjp backward vs autodiff through the oracle."""
    key = jax.random.PRNGKey(11)
    shape = (2, 2, 24, 8)
    q, k, v = (_rand(jax.random.fold_in(key, i), shape, jnp.float32) for i in range(3))

    def loss_kernel(q, k, v):
        return jnp.sum(jnp.sin(kernels.causal_attention(q, k, v)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.causal_attention(q, k, v)))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    t=st.integers(1, 96),
    d=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis_sweep(b, h, t, d, seed):
    key = jax.random.PRNGKey(seed)
    q, k, v = (_rand(jax.random.fold_in(key, i), (b, h, t, d), jnp.float32) for i in range(3))
    got = kernels.causal_attention(q, k, v)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "shape", [(1, 8), (4, 33, 48), (2, 5, 7, 16), (129, 64)]
)
def test_rmsnorm_matches_ref(shape):
    key = jax.random.PRNGKey(sum(shape))
    x = _rand(key, shape, jnp.float32)
    g = _rand(jax.random.fold_in(key, 1), shape[-1:], jnp.float32)
    np.testing.assert_allclose(
        kernels.rmsnorm(x, g), ref.rmsnorm(x, g), rtol=RTOL, atol=ATOL
    )


def test_rmsnorm_scale_invariance():
    """rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps)."""
    key = jax.random.PRNGKey(5)
    x = _rand(key, (8, 32), jnp.float32)
    g = jnp.ones((32,))
    np.testing.assert_allclose(
        kernels.rmsnorm(3.7 * x, g), kernels.rmsnorm(x, g), rtol=1e-4, atol=1e-4
    )


def test_rmsnorm_grad_matches_ref():
    key = jax.random.PRNGKey(13)
    x = _rand(key, (6, 24), jnp.float32)
    g = _rand(jax.random.fold_in(key, 1), (24,), jnp.float32)

    def lk(x, g):
        return jnp.sum(jnp.cos(kernels.rmsnorm(x, g)))

    def lr(x, g):
        return jnp.sum(jnp.cos(ref.rmsnorm(x, g)))

    gk = jax.grad(lk, argnums=(0, 1))(x, g)
    gr = jax.grad(lr, argnums=(0, 1))(x, g)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 200),
    d=st.sampled_from([4, 16, 48, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_hypothesis_sweep(rows, d, seed):
    key = jax.random.PRNGKey(seed)
    x = _rand(key, (rows, d), jnp.float32)
    g = _rand(jax.random.fold_in(key, 1), (d,), jnp.float32)
    np.testing.assert_allclose(
        kernels.rmsnorm(x, g), ref.rmsnorm(x, g), rtol=1e-4, atol=1e-4
    )
