"""L2 correctness: model graphs, flat-param packing, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import ModelConfig

CFG = ModelConfig(vocab=16, seq=12, d_model=32, n_layers=2, n_heads=2, d_ff=64,
                  use_pallas=True)
CFG_REF = ModelConfig(vocab=16, seq=12, d_model=32, n_layers=2, n_heads=2,
                      d_ff=64, use_pallas=False)


def _params(cfg, seed=0):
    return model.init_params(cfg, jnp.array([seed], jnp.int32))


def test_param_count_matches_layout():
    flat = _params(CFG)
    assert flat.shape == (model.param_count(CFG),)


def test_pack_unpack_roundtrip():
    flat = _params(CFG, seed=3)
    rt = model.pack(CFG, model.unpack(CFG, flat))
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(rt))


def test_forward_shapes_and_finite():
    flat = _params(CFG)
    tokens = jnp.zeros((4, CFG.seq), jnp.int32)
    lens = jnp.array([1, 3, 5, CFG.seq], jnp.int32)
    lg = model.forward(CFG, flat, tokens, lens)
    assert lg.shape == (4, CFG.vocab)
    assert np.isfinite(np.asarray(lg)).all()


def test_pallas_and_ref_models_agree():
    """Flipping use_pallas must not change the numerics (L1<->oracle swap)."""
    flat = _params(CFG)
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (4, CFG.seq), 0, CFG.vocab)
    lens = jnp.full((4,), CFG.seq, jnp.int32)
    a = model.forward(CFG, flat, tokens, lens)
    b = model.forward(CFG_REF, flat, tokens, lens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4)


def test_forward_depends_only_on_prefix():
    """Logits at position len-1 must ignore padding tokens past len."""
    flat = _params(CFG)
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (2, CFG.seq), 0, CFG.vocab)
    lens = jnp.array([4, 4], jnp.int32)
    tokens2 = tokens.at[:, 6:].set(7)  # mutate only the padding
    a = model.forward(CFG, flat, tokens, lens)
    b = model.forward(CFG, flat, tokens2, lens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_train_step_shapes():
    p = model.param_count(CFG)
    flat = _params(CFG)
    m = jnp.zeros((p,))
    v = jnp.zeros((p,))
    tokens = jnp.zeros((4, CFG.seq), jnp.int32)
    mask = jnp.ones((4, CFG.seq))
    adv = jnp.ones((4,))
    f2, m2, v2, loss = model.train_step(
        CFG, flat, m, v, jnp.array([1.0]), tokens, mask, adv
    )
    assert f2.shape == (p,) and m2.shape == (p,) and v2.shape == (p,)
    assert loss.shape == (1,) and np.isfinite(float(loss[0]))


def test_lm_training_reduces_loss():
    """A few Adam steps on a fixed batch must drive the LM loss down."""
    cfg = CFG_REF  # ref kernels: much faster under repeated jit in tests
    p = model.param_count(cfg)
    flat = _params(cfg, seed=7)
    m = jnp.zeros((p,))
    v = jnp.zeros((p,))
    key = jax.random.PRNGKey(9)
    tokens = jax.random.randint(key, (8, cfg.seq), 0, cfg.vocab)
    mask = jnp.ones((8, cfg.seq))
    adv = jnp.ones((8,))
    step_fn = jax.jit(lambda f, m, v, s: model.train_step(cfg, f, m, v, s, tokens, mask, adv))
    losses = []
    for s in range(12):
        flat, m, v, loss = step_fn(flat, m, v, jnp.array([float(s + 1)]))
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0] - 0.4, losses
    # and the trajectory should be essentially monotone at this scale
    assert sum(b < a for a, b in zip(losses, losses[1:])) >= 9, losses


def test_pg_loss_advantage_sign():
    """Positive advantage on an action raises its probability after a step."""
    cfg = CFG_REF
    p = model.param_count(cfg)
    flat = _params(cfg, seed=2)
    tokens = jnp.zeros((4, cfg.seq), jnp.int32).at[:, 1].set(5)
    mask = jnp.zeros((4, cfg.seq)).at[:, 0].set(1.0)  # position 0 predicts tokens[:,1]
    adv = jnp.ones((4,))
    lens = jnp.ones((4,), jnp.int32)

    def prob_of_5(f):
        lg = model.forward(cfg, f, tokens, lens)
        return float(jax.nn.softmax(lg, -1)[0, 5])

    before = prob_of_5(flat)
    m = jnp.zeros((p,))
    v = jnp.zeros((p,))
    for s in range(3):
        flat, m, v, _ = model.train_step(
            cfg, flat, m, v, jnp.array([float(s + 1)]), tokens, mask, adv
        )
    after = prob_of_5(flat)
    assert after > before


def test_init_is_seed_deterministic():
    a = _params(CFG, seed=42)
    b = _params(CFG, seed=42)
    c = _params(CFG, seed=43)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
