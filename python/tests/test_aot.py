"""AOT pipeline: lowering produces parseable HLO text with stable signatures."""

import json
import os

import pytest

from compile import aot, model
from compile.model import ModelConfig

TINY = ModelConfig(vocab=8, seq=6, d_model=16, n_layers=1, n_heads=2, d_ff=32,
                   use_pallas=False)  # ref kernels: keeps this test fast


@pytest.fixture(scope="module")
def arts():
    return aot.lower_all(TINY, rollout_batch=2, train_batch=3)


def test_all_three_graphs_lower(arts):
    assert set(arts) == {"agent_init", "agent_fwd", "agent_train"}
    for name, text in arts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_fwd_signature_shapes(arts):
    # entry computation must consume B×T tokens and produce B×V logits
    text = arts["agent_fwd"]
    p = model.param_count(TINY)
    assert f"f32[{p}]" in text
    assert "s32[2,6]" in text  # tokens
    assert "f32[2,8]" in text  # logits [B, V]


def test_train_signature_shapes(arts):
    text = arts["agent_train"]
    p = model.param_count(TINY)
    assert text.count(f"f32[{p}]") >= 3  # params, m, v (in and out)
    assert "s32[3,6]" in text  # tokens [BT, T]


def test_artifacts_on_disk_match_meta():
    """`make artifacts` output (if present) is self-consistent with meta.json."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    meta_path = os.path.join(art_dir, "meta.json")
    if not os.path.exists(meta_path):
        pytest.skip("artifacts not built")
    meta = json.load(open(meta_path))
    cfg = ModelConfig(
        vocab=meta["vocab"], seq=meta["seq"], d_model=meta["d_model"],
        n_layers=meta["n_layers"], n_heads=meta["n_heads"], d_ff=meta["d_ff"],
    )
    assert model.param_count(cfg) == meta["param_count"]
    for name in ("agent_init", "agent_fwd", "agent_train"):
        path = os.path.join(art_dir, f"{name}.hlo.txt")
        assert os.path.exists(path), path
        head = open(path).read(64)
        assert head.startswith("HloModule"), name
